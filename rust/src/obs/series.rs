//! Per-round time series: the `"kind":"series"` ledger line (DESIGN.md
//! §16).
//!
//! The paper's central claim is *dynamic adaptation* — NAC-FL varies
//! per-client compression as congestion varies — yet every observable
//! before this module was an end-of-run aggregate.  [`RoundSeries`] is a
//! runtime-off recorder (same contract as [`crate::obs::Telemetry`]:
//! the off handle is one `None` word and every method one branch)
//! threaded through the round loops of `sim::Session`, `des::engine`
//! and `des::flow`.  Each round the engine hands it one [`Sample`] of
//! per-round signals; the recorder keeps them in **fixed-size storage**:
//!
//! * below [`SERIES_CAP`] kept rounds the series is exact (stride 1);
//! * past the cap it decimates deterministically — drop every other
//!   kept sample and double the stride — so a million-round
//!   `pop:1000000` cell stays O(cap), and the kept rounds are a pure
//!   function of the total round count (byte-identical across threads,
//!   shards and reruns).
//!
//! One [`SeriesLine`] per run streams into the campaign ledger after
//! the run's telemetry.  The ledger is flat JSON, so each channel
//! travels as one comma-joined string; floats use the shared
//! shortest-round-trip policy with the literal `NaN` for
//! not-applicable slots (a flow-less run has no `congestion_s`, a
//! quorum-less run no `quorum_frac`).  Resume, merge and `nacfl
//! compact` dispatch on `"kind"` first, so series lines are invisible
//! to run keying; series-off runs write ledgers byte-identical to
//! pre-series builds (pinned by `tests/obs_system.rs`).

use crate::util::json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Maximum kept samples per series (the fixed-size budget).  The line
/// length is bounded by `cap * n_channels * ~25` bytes — well under
/// 64 KiB.
pub const SERIES_CAP: usize = 128;

/// Channel names, in wire/CSV order.  Adding a channel is a schema
/// extension: readers backfill missing channels with `NaN`.
pub const CHANNELS: [&str; 12] = [
    "level_mean",
    "level_max",
    "wire_bits",
    "btd_mean",
    "btd_eff",
    "congestion_s",
    "quorum_frac",
    "retrans",
    "queue_hw",
    "crashed",
    "wall_s",
    "cohort_mix",
];

/// One round's worth of signals.  Engines fill what they can observe
/// cheaply and leave the rest `NaN` (the analytic tier has no network,
/// an exogenous-BTD run no congestion, …).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Mean chosen compression level across participating clients.
    pub level_mean: f64,
    /// Max chosen compression level across participating clients.
    pub level_max: f64,
    /// Total wire bits uploaded this round.
    pub wire_bits: f64,
    /// Mean solo bit-transmission-delay state across clients.
    pub btd_mean: f64,
    /// Mean *effective* BTD actually experienced (flow cells).
    pub btd_eff: f64,
    /// Congestion seconds accrued this round (flow cells).
    pub congestion_s: f64,
    /// Delivered / expected participation fraction this round.
    pub quorum_frac: f64,
    /// Retransmission attempts this round.
    pub retrans: f64,
    /// Event-queue high-water mark so far.
    pub queue_hw: f64,
    /// Clients down (crashed) at the round boundary.
    pub crashed: f64,
    /// Cumulative simulated wall clock at round end.
    pub wall_s: f64,
    /// Mean class index of the sampled cohort (`pop:` cells).
    pub cohort_mix: f64,
}

impl Default for Sample {
    fn default() -> Self {
        Sample {
            level_mean: f64::NAN,
            level_max: f64::NAN,
            wire_bits: f64::NAN,
            btd_mean: f64::NAN,
            btd_eff: f64::NAN,
            congestion_s: f64::NAN,
            quorum_frac: f64::NAN,
            retrans: f64::NAN,
            queue_hw: f64::NAN,
            crashed: f64::NAN,
            wall_s: f64::NAN,
            cohort_mix: f64::NAN,
        }
    }
}

impl Sample {
    /// Channel accessor by wire name (must be one of [`CHANNELS`]).
    pub fn get(&self, channel: &str) -> f64 {
        match channel {
            "level_mean" => self.level_mean,
            "level_max" => self.level_max,
            "wire_bits" => self.wire_bits,
            "btd_mean" => self.btd_mean,
            "btd_eff" => self.btd_eff,
            "congestion_s" => self.congestion_s,
            "quorum_frac" => self.quorum_frac,
            "retrans" => self.retrans,
            "queue_hw" => self.queue_hw,
            "crashed" => self.crashed,
            "wall_s" => self.wall_s,
            "cohort_mix" => self.cohort_mix,
            _ => f64::NAN,
        }
    }

    fn set(&mut self, channel: &str, v: f64) {
        match channel {
            "level_mean" => self.level_mean = v,
            "level_max" => self.level_max = v,
            "wire_bits" => self.wire_bits = v,
            "btd_mean" => self.btd_mean = v,
            "btd_eff" => self.btd_eff = v,
            "congestion_s" => self.congestion_s = v,
            "quorum_frac" => self.quorum_frac = v,
            "retrans" => self.retrans = v,
            "queue_hw" => self.queue_hw = v,
            "crashed" => self.crashed = v,
            "wall_s" => self.wall_s = v,
            "cohort_mix" => self.cohort_mix = v,
            _ => {}
        }
    }
}

/// Kept rounds + samples behind the live handle.  Boxed so the off
/// state is a single `None` word (same pin as `Telemetry`).
#[derive(Clone, Debug)]
struct SeriesInner {
    /// Current decimation stride: round `r` is kept iff `r % stride == 0`.
    stride: u64,
    /// Rounds recorded so far (kept or not).
    rounds_total: u64,
    /// Kept round indices (0-based), ascending.
    rounds: Vec<u64>,
    /// Kept samples, parallel to `rounds`.
    samples: Vec<Sample>,
}

/// The per-run round-series recorder.  [`RoundSeries::off`] is free and
/// every method on it is a no-op; the engines guard their sampling code
/// with [`RoundSeries::is_on`] so the off path stays bit-identical and
/// allocation-free.
#[derive(Clone, Debug, Default)]
pub struct RoundSeries {
    inner: Option<Box<SeriesInner>>,
}

impl RoundSeries {
    /// The disabled handle: no allocation, every method a no-op.
    pub fn off() -> Self {
        RoundSeries { inner: None }
    }

    /// An enabled handle (stride 1, empty storage).
    pub fn on() -> Self {
        RoundSeries {
            inner: Some(Box::new(SeriesInner {
                stride: 1,
                rounds_total: 0,
                rounds: Vec::new(),
                samples: Vec::new(),
            })),
        }
    }

    /// Enabled (`on`) or disabled (`off`) by flag.
    pub fn new(enabled: bool) -> Self {
        if enabled {
            Self::on()
        } else {
            Self::off()
        }
    }

    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    /// Rounds recorded so far (kept or decimated away).
    pub fn rounds_total(&self) -> u64 {
        self.inner.as_ref().map(|i| i.rounds_total).unwrap_or(0)
    }

    /// Kept samples right now.
    pub fn len(&self) -> usize {
        self.inner.as_ref().map(|i| i.rounds.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current decimation stride (1 while exact).
    pub fn stride(&self) -> u64 {
        self.inner.as_ref().map(|i| i.stride).unwrap_or(1)
    }

    /// Record one round.  Kept iff the 0-based round index is a multiple
    /// of the current stride; when the kept count would exceed
    /// [`SERIES_CAP`], every other kept sample is dropped and the stride
    /// doubles — a pure function of the round count, so two recorders
    /// fed the same sample sequence hold identical storage.
    pub fn record(&mut self, s: Sample) {
        let Some(inner) = &mut self.inner else { return };
        let r = inner.rounds_total;
        inner.rounds_total += 1;
        if r % inner.stride != 0 {
            return;
        }
        inner.rounds.push(r);
        inner.samples.push(s);
        if inner.rounds.len() > SERIES_CAP {
            // Keep even positions: kept rounds stay ≡ 0 mod the doubled
            // stride, so future keeps splice in consistently.
            let mut w = 0usize;
            for i in (0..inner.rounds.len()).step_by(2) {
                inner.rounds[w] = inner.rounds[i];
                inner.samples[w] = inner.samples[i];
                w += 1;
            }
            inner.rounds.truncate(w);
            inner.samples.truncate(w);
            inner.stride *= 2;
        }
    }

    /// Snapshot as one ledger line under the run's coordinate key.
    /// `None` when the recorder is off or never saw a round (no line is
    /// streamed — an empty series carries no information).
    pub fn line(&self, key: &str) -> Option<SeriesLine> {
        let inner = self.inner.as_ref()?;
        if inner.rounds_total == 0 {
            return None;
        }
        Some(SeriesLine {
            scope: "run".to_string(),
            key: key.to_string(),
            cap: SERIES_CAP as u64,
            stride: inner.stride,
            rounds_total: inner.rounds_total,
            rounds: inner.rounds.clone(),
            samples: inner.samples.clone(),
        })
    }
}

/// A float inside a channel string: shortest exact round-trip for
/// finite values, the literal `NaN` for anything else (channels never
/// legitimately hold infinities).
fn fmt_channel(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "NaN".to_string()
    }
}

fn parse_channel(s: &str) -> Result<f64> {
    if s == "NaN" {
        return Ok(f64::NAN);
    }
    s.parse::<f64>()
        .map_err(|e| anyhow!("bad series channel value `{s}`: {e}"))
}

/// One flat `"kind":"series"` ledger line: a whole run's decimated
/// round series.  Schema-versioned alongside the ledger (`"schema":2`,
/// `"v":1`); every ledger reader dispatches on `"kind"` first, so
/// series lines are invisible to resume/merge keying.  Channels travel
/// as comma-joined strings (the ledger wire format is flat JSON).
#[derive(Clone, Debug)]
pub struct SeriesLine {
    /// Always `"run"` today (scope field mirrors [`super::TelemLine`]).
    pub scope: String,
    /// Run coordinate key.
    pub key: String,
    /// The recorder's cap when the line was written.
    pub cap: u64,
    /// Final decimation stride.
    pub stride: u64,
    /// Total rounds the run executed.
    pub rounds_total: u64,
    /// Kept round indices, ascending.
    pub rounds: Vec<u64>,
    /// Kept samples, parallel to `rounds`.
    pub samples: Vec<Sample>,
}

impl SeriesLine {
    /// One flat JSON object (a single ledger line, no trailing newline).
    /// `from_json(to_json(x))` re-serializes byte-for-byte.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"schema\":2,\"kind\":\"series\",\"v\":1,\"scope\":{},\"key\":{},\"cap\":{},\"stride\":{},\"rounds_total\":{}",
            json::string(&self.scope),
            json::string(&self.key),
            self.cap,
            self.stride,
            self.rounds_total,
        );
        let rounds: Vec<String> = self.rounds.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!(",\"rounds\":{}", json::string(&rounds.join(","))));
        for ch in CHANNELS {
            let vals: Vec<String> =
                self.samples.iter().map(|s| fmt_channel(s.get(ch))).collect();
            out.push_str(&format!(",\"{ch}\":{}", json::string(&vals.join(","))));
        }
        out.push('}');
        out
    }

    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_obj(&crate::exp::sink::parse_flat_object(line)?)
    }

    /// Build from an already-scanned flat object (shared with the
    /// distributed-ledger line dispatcher, `exp::dist::ledger`).
    pub(crate) fn from_obj(
        obj: &HashMap<String, crate::exp::sink::JsonVal>,
    ) -> Result<Self> {
        use crate::exp::sink::JsonVal;
        if obj.get("kind").and_then(JsonVal::as_str) != Some("series") {
            return Err(anyhow!("not a series line"));
        }
        match obj.get("v").and_then(JsonVal::as_u64) {
            Some(1) => {}
            other => return Err(anyhow!("unsupported series line version {other:?}")),
        }
        let s = |k: &str| -> Result<String> {
            obj.get(k)
                .and_then(JsonVal::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("series line missing string field `{k}`"))
        };
        let u = |k: &str| -> Result<u64> {
            obj.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| anyhow!("series line field `{k}` must be a non-negative integer"))
        };
        let rounds_s = s("rounds")?;
        let rounds: Vec<u64> = if rounds_s.is_empty() {
            Vec::new()
        } else {
            rounds_s
                .split(',')
                .map(|p| p.parse::<u64>().map_err(|e| anyhow!("bad round index `{p}`: {e}")))
                .collect::<Result<_>>()?
        };
        let mut samples = vec![Sample::default(); rounds.len()];
        for ch in CHANNELS {
            // Missing channels (older writers) backfill as NaN.
            let Some(vals) = obj.get(ch).and_then(JsonVal::as_str) else { continue };
            if vals.is_empty() {
                continue;
            }
            let parts: Vec<&str> = vals.split(',').collect();
            if parts.len() != rounds.len() {
                return Err(anyhow!(
                    "series channel `{ch}` has {} values for {} rounds",
                    parts.len(),
                    rounds.len()
                ));
            }
            for (slot, p) in samples.iter_mut().zip(parts) {
                slot.set(ch, parse_channel(p)?);
            }
        }
        Ok(SeriesLine {
            scope: s("scope")?,
            key: s("key")?,
            cap: u("cap")?,
            stride: u("stride")?,
            rounds_total: u("rounds_total")?,
            rounds,
            samples,
        })
    }

    /// CSV header for [`SeriesLine::csv`] rows.
    pub fn csv_header() -> String {
        format!("key,round,{}", CHANNELS.join(","))
    }

    /// One CSV row per kept sample (no header; see
    /// [`SeriesLine::csv_header`]).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        for (r, smp) in self.rounds.iter().zip(self.samples.iter()) {
            out.push_str(&format!("{},{}", self.key, r));
            for ch in CHANNELS {
                out.push(',');
                out.push_str(&fmt_channel(smp.get(ch)));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(v: f64) -> Sample {
        Sample { level_mean: v, level_max: v + 1.0, wall_s: v * 2.0, ..Sample::default() }
    }

    #[test]
    fn off_handle_is_a_no_op_and_allocation_free() {
        let mut s = RoundSeries::off();
        assert!(!s.is_on());
        s.record(sample(1.0));
        assert_eq!(s.rounds_total(), 0);
        assert_eq!(s.len(), 0);
        assert!(s.line("k").is_none());
        // The off handle is one Option word — nothing boxed.
        assert!(std::mem::size_of::<RoundSeries>() <= std::mem::size_of::<usize>());
    }

    #[test]
    fn exact_below_cap() {
        let mut s = RoundSeries::on();
        for r in 0..100 {
            s.record(sample(r as f64));
        }
        assert_eq!(s.len(), 100);
        assert_eq!(s.stride(), 1);
        let line = s.line("k").unwrap();
        assert_eq!(line.rounds, (0..100).collect::<Vec<u64>>());
        assert_eq!(line.samples[37].level_mean, 37.0);
    }

    #[test]
    fn decimation_is_bounded_and_deterministic() {
        let mut s = RoundSeries::on();
        for r in 0..1_000_000u64 {
            s.record(sample(r as f64));
        }
        assert_eq!(s.rounds_total(), 1_000_000);
        assert!(s.len() <= SERIES_CAP, "len {} > cap", s.len());
        assert!(s.stride().is_power_of_two());
        assert!(s.stride() > 1, "a million rounds must decimate");
        let line = s.line("k").unwrap();
        // Every kept round is a stride multiple, ascending, starting at 0.
        assert_eq!(line.rounds[0], 0);
        for w in line.rounds.windows(2) {
            assert!(w[0] < w[1]);
        }
        for &r in &line.rounds {
            assert_eq!(r % line.stride, 0);
            // The sample really is round r's sample.
            let i = line.rounds.iter().position(|&x| x == r).unwrap();
            assert_eq!(line.samples[i].level_mean, r as f64);
        }
        // Pure function of the round count: a second recorder fed the
        // same sequence lands on identical bytes.
        let mut s2 = RoundSeries::on();
        for r in 0..1_000_000u64 {
            s2.record(sample(r as f64));
        }
        assert_eq!(s2.line("k").unwrap().to_json(), line.to_json());
    }

    #[test]
    fn line_size_is_bounded_for_long_runs() {
        let mut s = RoundSeries::on();
        for r in 0..2_000_000u64 {
            // Worst-case-width floats in a few channels.
            let v = (r as f64) * 1.000000000137e-7 + 1.0 / 3.0;
            s.record(Sample {
                level_mean: v,
                level_max: v,
                wire_bits: v * 1e9,
                btd_mean: v,
                btd_eff: v,
                congestion_s: v,
                quorum_frac: v,
                retrans: v,
                queue_hw: v * 1e6,
                crashed: v,
                wall_s: v * 1e5,
                cohort_mix: v,
            });
        }
        let text = s.line("k").unwrap().to_json();
        assert!(text.len() < 64 * 1024, "series line {} bytes", text.len());
    }

    #[test]
    fn series_line_round_trips_byte_stable() {
        let mut s = RoundSeries::on();
        for r in 0..10 {
            let mut smp = sample(r as f64 / 3.0);
            smp.congestion_s = f64::NAN; // N/A channels survive as NaN
            smp.quorum_frac = 0.875;
            s.record(smp);
        }
        let line = s.line("homog:2|quant:inf|sim:60|sync|nacfl:1|0|0").unwrap();
        let text = line.to_json();
        assert!(text.contains("\"kind\":\"series\""), "{text}");
        assert!(text.contains("\"v\":1"), "{text}");
        let back = SeriesLine::from_json(&text).unwrap();
        assert_eq!(back.to_json(), text, "byte-stable round trip");
        assert_eq!(back.rounds_total, 10);
        assert!(back.samples[0].congestion_s.is_nan());
        assert_eq!(back.samples[0].quorum_frac, 0.875);
        assert!(back.samples[0].cohort_mix.is_nan(), "untouched channels stay NaN");
    }

    #[test]
    fn from_json_rejects_malformed_lines() {
        assert!(SeriesLine::from_json("").is_err());
        assert!(SeriesLine::from_json("{\"kind\":\"telem\"}").is_err(), "wrong kind");
        let mut s = RoundSeries::on();
        s.record(sample(1.0));
        let good = s.line("k").unwrap().to_json();
        assert!(SeriesLine::from_json(&good).is_ok());
        assert!(SeriesLine::from_json(&good[..good.len() / 2]).is_err(), "torn line");
        let v2 = good.replace("\"v\":1", "\"v\":2");
        assert!(SeriesLine::from_json(&v2).is_err(), "future series version");
        let short = good.replace("\"rounds\":\"0\"", "\"rounds\":\"0,1\"");
        assert!(SeriesLine::from_json(&short).is_err(), "channel length mismatch");
    }

    #[test]
    fn csv_rows_match_kept_samples() {
        let mut s = RoundSeries::on();
        for r in 0..3 {
            s.record(sample(r as f64));
        }
        let line = s.line("k").unwrap();
        assert!(SeriesLine::csv_header().starts_with("key,round,level_mean,"));
        let csv = line.csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("k,0,0.0,1.0,"), "{csv}");
    }
}

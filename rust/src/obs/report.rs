//! `nacfl report` — offline campaign health report over one or more
//! ledgers.
//!
//! Reads every ledger through the `"kind"` dispatcher
//! ([`read_dist_ledger`]), dedups runs by coordinate key across files,
//! and prints: per-ledger line accounting, throughput and wall
//! statistics, the per-run delay decomposition totals, a straggler
//! histogram (each run's `wait_s / wall` share, log-bucketed by
//! [`Histogram`]), aggregated telemetry counters and span histograms,
//! and — machine-greppable for CI — `coverage gaps: N` and
//! `span observations: N` summary lines.  With a plan the gap count is
//! exact (missing coordinate keys are listed); without one it falls
//! back to the ledger's own plan header.

use crate::exp::dist::ledger::{read_dist_ledger, DistLedger};
use crate::exp::plan::ExperimentPlan;
use crate::exp::sink::RunRecord;
use crate::obs::{Histogram, SeriesLine, TelemLine};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// A rendered report plus the counts CI branches on.
pub struct Report {
    pub text: String,
    /// Expected-but-missing runs (0 when no expectation is known).
    pub gaps: usize,
    /// Total span/histogram observations across all telem lines.
    pub span_observations: usize,
}

/// Whether a telem metric is a span-style duration histogram (wall ns
/// or simulated per-round seconds).
fn is_span_metric(metric: &str) -> bool {
    metric.ends_with("_ns") || metric.contains("round_s")
}

fn wall_stats(runs: &[&RunRecord]) -> String {
    let walls: Vec<f64> = runs.iter().map(|r| r.wall).filter(|w| w.is_finite()).collect();
    if walls.is_empty() {
        return "wall: no finite values".into();
    }
    let sum: f64 = walls.iter().sum();
    let min = walls.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = walls.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    format!(
        "wall: mean {:.3e} s, min {:.3e} s, max {:.3e} s over {} runs",
        sum / walls.len() as f64,
        min,
        max,
        walls.len()
    )
}

/// Render the non-empty buckets of a histogram as `[lo, hi) count` rows
/// (log-2 edges, the `obs` bucket geometry).
fn hist_rows(h: &Histogram) -> String {
    let mut out = String::new();
    let peak = h.buckets.iter().copied().max().unwrap_or(0).max(1);
    for (i, &c) in h.buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let lo = 2f64.powi(i as i32 - 32);
        let hi = 2f64.powi(i as i32 - 31);
        let bar = "#".repeat(((c as f64 / peak as f64) * 30.0).ceil() as usize);
        out.push_str(&format!("  [{lo:9.3e}, {hi:9.3e})  {c:>6}  {bar}\n"));
    }
    out
}

/// Build the report from already-read `(label, ledger)` pairs (pure;
/// `run_report` and the tests share it).  The label names each ledger
/// in the per-file accounting section.
pub fn build_report(
    ledgers: &[(String, DistLedger)],
    plan: Option<&ExperimentPlan>,
) -> Report {
    let mut out = String::new();

    // Per-ledger accounting + pooled lines.
    let mut by_key: BTreeMap<String, &RunRecord> = BTreeMap::new();
    let mut telem: Vec<&TelemLine> = Vec::new();
    let mut n_run_lines = 0usize;
    let mut n_torn = 0usize;
    let mut header = None;
    for (label, led) in ledgers {
        out.push_str(&format!(
            "{label}: {} run, {} claim, {} telem, {} series, {} torn, {} legacy line(s)\n",
            led.runs.len(),
            led.claims.len(),
            led.telem.len(),
            led.series.len(),
            led.n_torn,
            led.n_legacy
        ));
        n_run_lines += led.runs.len();
        n_torn += led.n_torn;
        for r in &led.runs {
            by_key.insert(r.key(), r);
        }
        telem.extend(led.telem.iter());
        if header.is_none() {
            header = led.header.as_ref();
        }
    }
    let runs: Vec<&RunRecord> = by_key.values().copied().collect();
    let duplicates = n_run_lines - runs.len();
    let converged = runs.iter().filter(|r| r.converged).count();
    out.push_str(&format!(
        "\nunique runs: {} ({duplicates} duplicate line(s) across ledgers)\n",
        runs.len()
    ));
    out.push_str(&format!("converged: {converged}/{}\n", runs.len()));
    out.push_str(&format!("{}\n", wall_stats(&runs)));

    // Delay decomposition totals (runs that predate the decomposition
    // serialize NaN and are skipped).
    let (mut up, mut comp, mut wait, mut n_dec) = (0.0f64, 0.0f64, 0.0f64, 0usize);
    for r in &runs {
        if r.upload_s.is_finite() && r.compute_s.is_finite() && r.wait_s.is_finite() {
            up += r.upload_s;
            comp += r.compute_s;
            wait += r.wait_s;
            n_dec += 1;
        }
    }
    if n_dec > 0 {
        let total = up + comp + wait;
        let pct = |v: f64| if total.abs() > 0.0 { v / total * 100.0 } else { 0.0 };
        out.push_str(&format!(
            "delay decomposition ({n_dec} runs): upload {up:.3e} s ({:.0}%), \
             compute {comp:.3e} s ({:.0}%), wait {wait:.3e} s ({:.0}%)\n",
            pct(up),
            pct(comp),
            pct(wait)
        ));
    }

    // Fault-channel health (DESIGN.md §14): runs carrying a non-trivial
    // `faults` coordinate report their retransmission cost and mean
    // aggregation quorum.  NaN fields (pre-fault or backfilled lines)
    // are skipped, mirroring the decomposition's rule.
    let faulty: Vec<&RunRecord> = runs.iter().copied().filter(|r| r.faults != "none").collect();
    if !faulty.is_empty() {
        let retrans: Vec<f64> =
            faulty.iter().map(|r| r.retrans_s).filter(|v| v.is_finite()).collect();
        let quorum: Vec<f64> =
            faulty.iter().map(|r| r.quorum_frac).filter(|v| v.is_finite()).collect();
        let rsum: f64 = retrans.iter().sum();
        out.push_str(&format!(
            "faults: {} faulty run(s); retrans {rsum:.3e} s over {} run(s)",
            faulty.len(),
            retrans.len()
        ));
        if !quorum.is_empty() {
            out.push_str(&format!(
                ", mean quorum {:.3}",
                quorum.iter().sum::<f64>() / quorum.len() as f64
            ));
        }
        out.push('\n');
    }

    // Population rollup (DESIGN.md §15): runs carrying a non-trivial
    // `pop` coordinate report their sampled-K-per-round mean and the
    // aggregate per-class participation histogram.  NaN/empty fields
    // (pre-pop or backfilled lines) are skipped like the fault rules.
    let popped: Vec<&RunRecord> = runs.iter().copied().filter(|r| r.pop != "none").collect();
    if !popped.is_empty() {
        let ks: Vec<f64> =
            popped.iter().map(|r| r.sampled_k).filter(|v| v.is_finite()).collect();
        out.push_str(&format!("pop: {} population run(s)", popped.len()));
        if !ks.is_empty() {
            out.push_str(&format!(
                ", mean sampled K {:.0} over {} run(s)",
                ks.iter().sum::<f64>() / ks.len() as f64,
                ks.len()
            ));
        }
        out.push('\n');
        let mut classes: BTreeMap<usize, u64> = BTreeMap::new();
        let mut sampled_total = 0u64;
        for r in &popped {
            for part in r.participation.split(',').filter(|p| !p.is_empty()) {
                if let Some((c, n)) = part.split_once(':') {
                    if let (Ok(c), Ok(n)) = (c.parse::<usize>(), n.parse::<u64>()) {
                        *classes.entry(c).or_insert(0) += n;
                        sampled_total += n;
                    }
                }
            }
        }
        if sampled_total > 0 {
            out.push_str("participation by class:\n");
            for (c, n) in &classes {
                out.push_str(&format!(
                    "  class{c}: {n} ({:.1}%)\n",
                    *n as f64 / sampled_total as f64 * 100.0
                ));
            }
        }
    }

    // Round-series rollup: one row per recorded run (latest series line
    // per key across ledgers) — storage accounting plus the compression
    // level's trajectory endpoints, the quick "did the policy adapt"
    // check without leaving the terminal.
    let mut series_by_key: BTreeMap<&str, &SeriesLine> = BTreeMap::new();
    for (_, led) in ledgers {
        for s in &led.series {
            series_by_key.insert(&s.key, s);
        }
    }
    if !series_by_key.is_empty() {
        out.push_str(&format!("\nround series ({} run(s)):\n", series_by_key.len()));
        for (k, s) in series_by_key.iter().take(10) {
            let lvl = |o: Option<&crate::obs::Sample>| o.map(|x| x.level_mean).unwrap_or(f64::NAN);
            out.push_str(&format!(
                "  {k}: {} of {} round(s) kept (stride {}), level {:.2} -> {:.2}\n",
                s.rounds.len(),
                s.rounds_total,
                s.stride,
                lvl(s.samples.first()),
                lvl(s.samples.last())
            ));
        }
        if series_by_key.len() > 10 {
            out.push_str(&format!("  ... and {} more\n", series_by_key.len() - 10));
        }
    }

    // Straggler histogram: each run's wait share of its wall.  A share
    // near 0 means upload-bound; near 1 means one slow client dominates.
    let mut straggler = Histogram::default();
    for r in &runs {
        if r.wall.is_finite() && r.wall > 0.0 && r.wait_s.is_finite() {
            straggler.observe((r.wait_s / r.wall).max(0.0));
        }
    }
    if straggler.count > 0 {
        out.push_str(&format!(
            "\nstraggler shares (wait_s / wall, {} runs, mean {:.3}):\n",
            straggler.count,
            straggler.mean()
        ));
        out.push_str(&hist_rows(&straggler));
    }

    // Aggregated telemetry: counters summed per metric, histograms
    // merged per metric (across runs, workers and ledgers).
    let mut counters: BTreeMap<&str, u64> = BTreeMap::new();
    let mut hists: BTreeMap<&str, Histogram> = BTreeMap::new();
    let mut steals = 0u64;
    for t in &telem {
        if let Some(v) = t.counter {
            *counters.entry(&t.metric).or_insert(0) += v;
            if t.metric == "dist.steals" {
                steals += v;
            }
        }
        if let Some(h) = &t.hist {
            hists.entry(&t.metric).or_insert_with(Histogram::default).merge(h);
        }
    }
    if !counters.is_empty() || !hists.is_empty() {
        out.push_str("\ntelemetry:\n");
        for (m, v) in &counters {
            out.push_str(&format!("  {m}: {v}\n"));
        }
        for (m, h) in &hists {
            out.push_str(&format!(
                "  {m}: n {} mean {:.3e} min {:.3e} max {:.3e}\n",
                h.count,
                h.mean(),
                h.min,
                h.max
            ));
        }
    }

    // Coverage: exact against a plan, count-only against a header.
    let gaps = if let Some(p) = plan {
        let have: BTreeSet<String> = by_key.keys().cloned().collect();
        let missing: Vec<String> =
            p.cells().iter().map(|c| c.key()).filter(|k| !have.contains(k)).collect();
        if !missing.is_empty() {
            out.push_str("\nmissing runs:\n");
            for k in missing.iter().take(10) {
                out.push_str(&format!("  {k}\n"));
            }
            if missing.len() > 10 {
                out.push_str(&format!("  ... and {} more\n", missing.len() - 10));
            }
        }
        missing.len()
    } else if let Some(h) = header {
        h.n_runs.saturating_sub(runs.len())
    } else {
        0
    };
    let span_observations: usize = hists
        .iter()
        .filter(|(m, _)| is_span_metric(m))
        .map(|(_, h)| h.count as usize)
        .sum();
    out.push_str(&format!(
        "\ncoverage gaps: {gaps}\nspan observations: {span_observations}\n\
         duplicate records: {duplicates}\nsteals: {steals}\ntorn lines: {n_torn}\n"
    ));

    Report { text: out, gaps, span_observations }
}

/// Read `paths` and build the report (the `nacfl report` entry point).
pub fn run_report(paths: &[&Path], plan: Option<&ExperimentPlan>) -> Result<Report> {
    let mut ledgers = Vec::with_capacity(paths.len());
    for p in paths {
        ledgers.push((p.display().to_string(), read_dist_ledger(p)?));
    }
    Ok(build_report(&ledgers, plan))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(policy: &str, seed: u64, wall: f64) -> RunRecord {
        RunRecord {
            campaign: "t".into(),
            scenario: "homog:2".into(),
            compressor: "quant:inf".into(),
            tier: "sim:60".into(),
            discipline: "sync".into(),
            faults: "none".into(),
            policy: policy.into(),
            data_seed: 0,
            seed,
            config: "fp".into(),
            wall,
            rounds: 10,
            converged: true,
            aggregations: 10,
            dropped: 0,
            late: 0,
            upload_s: wall * 0.75,
            compute_s: 0.0,
            wait_s: wall * 0.25,
            congestion_s: 0.0,
            retrans_s: f64::NAN,
            quorum_frac: f64::NAN,
            pop: "none".into(),
            sampled_k: f64::NAN,
            participation: String::new(),
            trace: None,
        }
    }

    #[test]
    fn report_dedups_and_counts_gaps_against_plan() {
        let plan = ExperimentPlan::builder("t")
            .policies(["fixed:2", "nacfl:1"])
            .seeds([0, 1])
            .build()
            .unwrap();
        // Two ledgers covering 3 of the cells, one duplicated.
        let mut a = DistLedger::default();
        let mut b = DistLedger::default();
        let cells = plan.cells();
        let mk = |c: &crate::exp::plan::PlanCell| {
            let mut r = rec(&c.policy, c.seed, 100.0);
            r.scenario = c.scenario.label();
            r.compressor = c.compressor.clone();
            r.tier = c.tier.label();
            r.discipline = c.discipline.label();
            r.data_seed = c.data_seed;
            r
        };
        a.runs.push(mk(&cells[0]));
        a.runs.push(mk(&cells[1]));
        b.runs.push(mk(&cells[1]));
        b.runs.push(mk(&cells[2]));
        let n = plan.n_runs();
        let report = build_report(
            &[("a".into(), a), ("b".into(), b)],
            Some(&plan),
        );
        assert_eq!(report.gaps, n - 3, "every uncovered cell is a gap");
        assert!(report.text.contains("unique runs: 3 (1 duplicate line(s)"), "{}", report.text);
        assert!(report.text.contains(&format!("coverage gaps: {}", n - 3)), "{}", report.text);
        assert!(report.text.contains("missing runs:"), "{}", report.text);
        assert!(report.text.contains("straggler shares"), "{}", report.text);
        assert!(report.text.contains("delay decomposition (3 runs)"), "{}", report.text);
    }

    #[test]
    fn fault_section_appears_only_for_faulty_runs_and_skips_nan() {
        // A fault-free ledger has no fault section at all.
        let mut clean = DistLedger::default();
        clean.runs.push(rec("fixed:2", 0, 10.0));
        let report = build_report(&[("l".into(), clean)], None);
        assert!(!report.text.contains("faults:"), "{}", report.text);

        // Two faulty runs, one resumed from a line written before the
        // fault fields existed (NaN backfill): counted as faulty, but
        // excluded from the retrans total and the quorum mean.
        let mut led = DistLedger::default();
        let mut fresh = rec("fixed:2", 1, 10.0);
        fresh.faults = "loss:0.2".into();
        fresh.retrans_s = 3.0;
        fresh.quorum_frac = 0.5;
        let mut stale = rec("fixed:2", 2, 10.0);
        stale.faults = "loss:0.2".into(); // retrans_s/quorum_frac stay NaN
        led.runs.push(fresh);
        led.runs.push(stale);
        let report = build_report(&[("l".into(), led)], None);
        assert!(
            report.text.contains("faults: 2 faulty run(s); retrans 3.000e0 s over 1 run(s)"),
            "{}",
            report.text
        );
        assert!(report.text.contains("mean quorum 0.500"), "{}", report.text);
    }

    #[test]
    fn pop_section_appears_only_for_pop_runs_and_skips_backfill() {
        // A pop-free ledger has no population section at all.
        let mut clean = DistLedger::default();
        clean.runs.push(rec("fixed:2", 0, 10.0));
        let report = build_report(&[("l".into(), clean)], None);
        assert!(!report.text.contains("pop:"), "{}", report.text);

        // Two pop runs, one resumed from a line written before the pop
        // fields existed (NaN/empty backfill): counted as population
        // runs, excluded from the K mean and the class histogram.
        let mut led = DistLedger::default();
        let mut fresh = rec("fixed:2", 1, 10.0);
        fresh.pop = "pop:1000000:k1000:classeshilo".into();
        fresh.sampled_k = 1000.0;
        fresh.participation = "0:750,1:250".into();
        let mut stale = rec("fixed:2", 2, 10.0);
        stale.pop = "pop:1000000:k1000:classeshilo".into(); // NaN/empty backfill
        led.runs.push(fresh);
        led.runs.push(stale);
        let report = build_report(&[("l".into(), led)], None);
        assert!(
            report.text.contains("pop: 2 population run(s), mean sampled K 1000 over 1 run(s)"),
            "{}",
            report.text
        );
        assert!(report.text.contains("class0: 750 (75.0%)"), "{}", report.text);
        assert!(report.text.contains("class1: 250 (25.0%)"), "{}", report.text);
    }

    #[test]
    fn span_observations_count_duration_histograms_only() {
        let mut led = DistLedger::default();
        let mut spans = Histogram::default();
        spans.observe(1.0);
        spans.observe(2.0);
        let mut other = Histogram::default();
        other.observe(5.0);
        let line = |metric: &str, hist| TelemLine {
            scope: "run".into(),
            key: "k".into(),
            metric: metric.into(),
            counter: None,
            hist: Some(hist),
        };
        led.telem.push(line("sim.round_s", spans));
        led.telem.push(line("solver.solve_ns", spans));
        led.telem.push(line("dist.lease_age_s", other));
        led.telem.push(TelemLine {
            scope: "campaign".into(),
            key: "w".into(),
            metric: "dist.steals".into(),
            counter: Some(3),
            hist: None,
        });
        let report = build_report(&[("l".into(), led)], None);
        assert_eq!(report.span_observations, 4, "round_s + _ns, not lease ages");
        assert!(report.text.contains("span observations: 4"), "{}", report.text);
        assert!(report.text.contains("steals: 3"), "{}", report.text);
        assert_eq!(report.gaps, 0, "no plan, no header -> no expectation");
        assert!(report.text.contains("coverage gaps: 0"), "{}", report.text);
    }

    #[test]
    fn series_section_lists_kept_rounds_per_run() {
        use crate::obs::{RoundSeries, Sample};
        let mut led = DistLedger::default();
        let r = rec("nacfl:1", 0, 10.0);
        let mut ser = RoundSeries::on();
        for i in 0..5 {
            ser.record(Sample {
                level_mean: 2.0 + i as f64 * 0.5,
                wall_s: i as f64,
                ..Sample::default()
            });
        }
        led.series.push(ser.line(&r.key()).unwrap());
        led.runs.push(r);
        let report = build_report(&[("l".into(), led)], None);
        assert!(report.text.contains("1 series"), "{}", report.text);
        assert!(report.text.contains("round series (1 run(s)):"), "{}", report.text);
        assert!(
            report.text.contains("5 of 5 round(s) kept (stride 1), level 2.00 -> 4.00"),
            "{}",
            report.text
        );

        // No series lines -> no section at all.
        let mut clean = DistLedger::default();
        clean.runs.push(rec("fixed:2", 0, 10.0));
        let report = build_report(&[("l".into(), clean)], None);
        assert!(!report.text.contains("round series"), "{}", report.text);
    }

    #[test]
    fn header_fallback_counts_gaps_without_listing_keys() {
        let plan = ExperimentPlan::builder("t").build().unwrap();
        let mut led = DistLedger::default();
        led.header = Some(crate::exp::dist::PlanHeader::for_plan(&plan));
        led.runs.push(rec("nacfl:1", 0, 1.0));
        let report = build_report(&[("l".into(), led)], None);
        assert_eq!(report.gaps, plan.n_runs() - 1);
        assert!(!report.text.contains("missing runs:"), "{}", report.text);
    }
}

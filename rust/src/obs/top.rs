//! `nacfl top` — a live fleet view over a campaign ledger.
//!
//! Tails a (possibly multi-worker, concurrently-appended) distributed
//! ledger and renders one terminal frame per refresh: per-group
//! completion bars with running mean walls, worker liveness and lease
//! ages from the claim lines, campaign-scope telemetry counters, and a
//! wall-clock-per-run canvas on the `metrics::plot` renderer.  Lines
//! go through the ordinary [`DistLedger::ingest_line`] dispatcher, so
//! torn lines from a worker mid-write are skipped, never fatal — `top`
//! can be started *before* the first worker creates the file ("waiting
//! for ledger").
//!
//! Reading is **incremental** ([`LedgerTail`]): the loop keeps the
//! dispatched state and a byte cursor, and each frame parses only the
//! lines appended since the previous one — a frame over a long fleet
//! ledger costs the new lines, not a full re-read.  Truncation (the
//! ledger compacted or rotated underneath us) is detected by the file
//! shrinking below the cursor and triggers one full re-read.

use crate::exp::dist::ledger::{now_unix, DistLedger};
use crate::exp::plan::ExperimentPlan;
use crate::exp::sink::RunRecord;
use crate::metrics::plot::{render, Series};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Width of the per-group completion bars.
const BAR_W: usize = 24;

/// Sparkline glyph ramp (eighth blocks, low to high).
const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Max kept samples shown per sparkline (the newest ones).
const SPARK_W: usize = 32;

/// Render the last `width` finite values as a block sparkline, scaled
/// to their own min..max (a flat series renders all-low).  Empty when
/// nothing is finite.
fn sparkline(vals: &[f64], width: usize) -> String {
    let vals: Vec<f64> = vals.iter().copied().filter(|v| v.is_finite()).collect();
    if vals.is_empty() {
        return String::new();
    }
    let tail = &vals[vals.len().saturating_sub(width)..];
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in tail {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(f64::EPSILON);
    tail.iter()
        .map(|&v| SPARK[(((v - lo) / span) * 7.0).round() as usize])
        .collect()
}

/// The group axis shown in the bars: every coordinate except policy and
/// seeds (matches the paper-table grouping in `exp::sink`, including
/// the faults suffix on non-trivial fault coordinates).
fn group_key(r: &RunRecord) -> String {
    let mut k = format!("{}|{}|{}|{}", r.scenario, r.compressor, r.tier, r.discipline);
    if r.faults != "none" {
        k.push('|');
        k.push_str(&r.faults);
    }
    if r.pop != "none" {
        k.push('|');
        k.push_str(&r.pop);
    }
    k
}

fn bar(done: usize, total: usize) -> String {
    let filled = if total == 0 {
        0
    } else {
        ((done as f64 / total as f64) * BAR_W as f64).round() as usize
    }
    .min(BAR_W);
    format!("[{}{}]", "#".repeat(filled), "-".repeat(BAR_W - filled))
}

/// Render one frame from an already-read ledger.  Returns the frame
/// text and whether the campaign is complete (every expected run has a
/// record).  Pure — the `tests` below and `run_top` share it.
pub fn render_frame(
    led: &DistLedger,
    plan: Option<&ExperimentPlan>,
    now: u64,
) -> (String, bool) {
    // Dedup runs by coordinate key, last writer wins (records are
    // idempotent bits, so "last" is cosmetic).
    let mut by_key: BTreeMap<String, &RunRecord> = BTreeMap::new();
    for r in &led.runs {
        by_key.insert(r.key(), r);
    }
    let done = by_key.len();
    let total = plan
        .map(|p| p.n_runs())
        .or_else(|| led.header.as_ref().map(|h| h.n_runs))
        .unwrap_or(0);
    let name = plan
        .map(|p| p.name.clone())
        .or_else(|| led.header.as_ref().map(|h| h.campaign.clone()))
        .unwrap_or_else(|| "campaign".into());

    let mut out = String::new();
    if total > 0 {
        out.push_str(&format!(
            "{name}: {done}/{total} runs ({:.0}%)\n",
            done as f64 / total as f64 * 100.0
        ));
    } else {
        out.push_str(&format!("{name}: {done} runs (total unknown — pass --plan)\n"));
    }
    out.push_str(&format!(
        "lines: {} run, {} claim, {} telem, {} series, {} torn\n\n",
        led.runs.len(),
        led.claims.len(),
        led.telem.len(),
        led.series.len(),
        led.n_torn
    ));

    // Per-group bars: expected counts from the plan when we have one,
    // else groups observed so far with unknown totals.
    let mut expected: BTreeMap<String, usize> = BTreeMap::new();
    if let Some(p) = plan {
        for cell in p.cells() {
            let mut r = format!(
                "{}|{}|{}|{}",
                cell.scenario.label(),
                cell.compressor,
                cell.tier.label(),
                cell.discipline.label()
            );
            if cell.faults != "none" {
                r.push('|');
                r.push_str(&cell.faults);
            }
            if cell.pop != "none" {
                r.push('|');
                r.push_str(&cell.pop);
            }
            *expected.entry(r).or_insert(0) += 1;
        }
    }
    let mut got: BTreeMap<String, (usize, f64, usize)> = BTreeMap::new();
    for r in by_key.values() {
        let e = got.entry(group_key(r)).or_insert((0, 0.0, 0));
        e.0 += 1;
        if r.wall.is_finite() {
            e.1 += r.wall;
            e.2 += 1;
        }
    }
    for g in got.keys() {
        expected.entry(g.clone()).or_insert(0);
    }
    for (g, n_exp) in &expected {
        let (n, wall_sum, n_wall) = got.get(g).copied().unwrap_or((0, 0.0, 0));
        let mean = if n_wall > 0 {
            format!("mean {:.3e} s", wall_sum / n_wall as f64)
        } else {
            "mean -".into()
        };
        if *n_exp > 0 {
            out.push_str(&format!("{} {n:>4}/{n_exp:<4} {mean:<16} {g}\n", bar(n, *n_exp)));
        } else {
            out.push_str(&format!("{} {n:>4}      {mean:<16} {g}\n", bar(1, 1)));
        }
    }

    // Per-group compression-level sparkline from the latest round-series
    // line whose run record landed in the group — watch the policy adapt
    // live as the fleet streams `--series` lines.
    let mut series_by_group: BTreeMap<String, &crate::obs::SeriesLine> = BTreeMap::new();
    for s in &led.series {
        if let Some(r) = by_key.get(&s.key) {
            series_by_group.insert(group_key(r), s);
        }
    }
    if !series_by_group.is_empty() {
        out.push('\n');
        for (g, s) in &series_by_group {
            let levels: Vec<f64> = s.samples.iter().map(|x| x.level_mean).collect();
            let sp = sparkline(&levels, SPARK_W);
            if !sp.is_empty() {
                out.push_str(&format!("level {sp} {g}\n"));
            }
        }
    }

    // Fault-channel rollup over completed faulty runs (retrans totals
    // and mean quorum, NaN backfill skipped like the report's).
    let faulty: Vec<&&RunRecord> = by_key.values().filter(|r| r.faults != "none").collect();
    if !faulty.is_empty() {
        let retrans: f64 =
            faulty.iter().map(|r| r.retrans_s).filter(|v| v.is_finite()).sum();
        let quorum: Vec<f64> =
            faulty.iter().map(|r| r.quorum_frac).filter(|v| v.is_finite()).collect();
        let q = if quorum.is_empty() {
            "-".into()
        } else {
            format!("{:.3}", quorum.iter().sum::<f64>() / quorum.len() as f64)
        };
        out.push_str(&format!(
            "\nfaults: {} run(s), retrans {retrans:.3e} s, mean quorum {q}\n",
            faulty.len()
        ));
    }

    // Population rollup over completed pop runs: sampled-K per round
    // and the aggregate per-class participation histogram.
    let popped: Vec<&&RunRecord> = by_key.values().filter(|r| r.pop != "none").collect();
    if !popped.is_empty() {
        let ks: Vec<f64> = popped.iter().map(|r| r.sampled_k).filter(|v| v.is_finite()).collect();
        let k = if ks.is_empty() {
            "-".into()
        } else {
            format!("{:.0}", ks.iter().sum::<f64>() / ks.len() as f64)
        };
        let mut classes: BTreeMap<usize, u64> = BTreeMap::new();
        for r in &popped {
            for part in r.participation.split(',').filter(|p| !p.is_empty()) {
                if let Some((c, n)) = part.split_once(':') {
                    if let (Ok(c), Ok(n)) = (c.parse::<usize>(), n.parse::<u64>()) {
                        *classes.entry(c).or_insert(0) += n;
                    }
                }
            }
        }
        let hist: Vec<String> =
            classes.iter().map(|(c, n)| format!("class{c} {n}")).collect();
        out.push_str(&format!(
            "\npop: {} run(s), mean sampled K {k}, participation {}\n",
            popped.len(),
            if hist.is_empty() { "-".into() } else { hist.join(", ") }
        ));
    }

    // Worker table from the claim lines: live/expired leases + ages.
    let mut workers: BTreeMap<&str, (usize, u64, bool)> = BTreeMap::new();
    for c in led.claims.values() {
        let e = workers.entry(&c.worker).or_insert((0, 0, false));
        e.0 += 1;
        e.1 = e.1.max(c.ts);
        e.2 |= c.live(now);
    }
    if !workers.is_empty() {
        out.push('\n');
        for (w, (n_claims, last_ts, live)) in &workers {
            out.push_str(&format!(
                "worker {w}: {n_claims} claim(s), lease age {}s, {}\n",
                now.saturating_sub(*last_ts),
                if *live { "LIVE" } else { "expired" }
            ));
        }
    }

    // Campaign-scope telemetry (per-worker runs started/completed/
    // stolen, lease renewals) — counters only, summed per metric.
    let mut counters: BTreeMap<&str, u64> = BTreeMap::new();
    for t in &led.telem {
        if t.scope == "campaign" {
            if let Some(v) = t.counter {
                *counters.entry(&t.metric).or_insert(0) += v;
            }
        }
    }
    if !counters.is_empty() {
        out.push('\n');
        for (m, v) in &counters {
            out.push_str(&format!("{m}: {v}\n"));
        }
    }

    // Wall-per-completed-run canvas (file order): a live straggler
    // spotter — spikes are the runs dominating the remaining time.
    let points: Vec<(f64, f64)> = by_key
        .values()
        .enumerate()
        .filter(|(_, r)| r.wall.is_finite())
        .map(|(i, r)| (i as f64, r.wall))
        .collect();
    if !points.is_empty() {
        out.push('\n');
        out.push_str(&render(
            &[Series { label: "wall s per completed run".into(), points, glyph: '*' }],
            60,
            8,
        ));
    }

    let complete = total > 0 && done >= total;
    (out, complete)
}

/// Incremental ledger reader: the dispatched [`DistLedger`] state plus
/// a byte cursor.  [`LedgerTail::poll`] ingests only the bytes appended
/// since the last poll, advancing the cursor past *complete* lines only
/// — a torn final line (a worker mid-write) is retried whole on the
/// next poll once its newline lands, instead of being half-consumed.
/// A file shorter than the cursor means the ledger was compacted or
/// rotated underneath us: the state resets and the file is re-read
/// from the start.
#[derive(Default)]
pub struct LedgerTail {
    led: DistLedger,
    cursor: u64,
}

impl LedgerTail {
    pub fn new() -> Self {
        Self::default()
    }

    /// Byte offset of the first unconsumed byte (diagnostics/tests).
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Ingest everything appended since the previous poll and return
    /// the up-to-date state.  Errors mirror `read_dist_ledger`: an
    /// unreadable file or conflicting plan headers.
    pub fn poll(&mut self, path: &Path) -> Result<&DistLedger> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("reading campaign ledger {}", path.display()))?;
        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
        if len < self.cursor {
            self.led = DistLedger::default();
            self.cursor = 0;
        }
        if len == self.cursor {
            return Ok(&self.led);
        }
        f.seek(SeekFrom::Start(self.cursor))
            .with_context(|| format!("seeking in {}", path.display()))?;
        let mut buf = Vec::with_capacity((len - self.cursor) as usize);
        f.take(len - self.cursor)
            .read_to_end(&mut buf)
            .with_context(|| format!("reading {}", path.display()))?;
        // Consume up to the last newline; the remainder is a line still
        // being written and stays for the next poll.
        let Some(last_nl) = buf.iter().rposition(|&b| b == b'\n') else {
            return Ok(&self.led);
        };
        for line in String::from_utf8_lossy(&buf[..last_nl]).lines() {
            self.led
                .ingest_line(line)
                .with_context(|| format!("ledger {}", path.display()))?;
        }
        self.cursor += last_nl as u64 + 1;
        Ok(&self.led)
    }
}

/// The `nacfl top` loop: clear the terminal, render a frame, sleep,
/// repeat — until the campaign completes, `frames` frames have been
/// drawn (`0` = unbounded), or `once` short-circuits after one frame.
/// A missing or unreadable ledger renders a waiting frame instead of
/// erroring, so `top` can start before the first worker.  Frames after
/// the first parse only the appended ledger lines ([`LedgerTail`]).
pub fn run_top(
    path: &Path,
    plan: Option<&ExperimentPlan>,
    interval_s: f64,
    frames: usize,
    once: bool,
) -> Result<()> {
    let mut drawn = 0usize;
    let mut tail = LedgerTail::new();
    loop {
        let frame = match tail.poll(path) {
            Ok(led) => render_frame(led, plan, now_unix()),
            Err(_) => (
                format!("waiting for ledger {} ...\n", path.display()),
                false,
            ),
        };
        if !once {
            // ANSI clear + home; harmless when piped to a file.
            print!("\x1b[2J\x1b[H");
        }
        print!("{}", frame.0);
        use std::io::Write;
        std::io::stdout().flush().ok();
        drawn += 1;
        if frame.1 || once || (frames > 0 && drawn >= frames) {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval_s.max(0.05)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::dist::ledger::ClaimRecord;
    use crate::obs::TelemLine;

    fn rec(policy: &str, seed: u64, wall: f64) -> RunRecord {
        RunRecord {
            campaign: "t".into(),
            scenario: "homog:2".into(),
            compressor: "quant:inf".into(),
            tier: "sim:60".into(),
            discipline: "sync".into(),
            faults: "none".into(),
            policy: policy.into(),
            data_seed: 0,
            seed,
            config: "fp".into(),
            wall,
            rounds: 10,
            converged: true,
            aggregations: 10,
            dropped: 0,
            late: 0,
            upload_s: wall,
            compute_s: 0.0,
            wait_s: 0.0,
            congestion_s: 0.0,
            retrans_s: f64::NAN,
            quorum_frac: f64::NAN,
            pop: "none".into(),
            sampled_k: f64::NAN,
            participation: String::new(),
            trace: None,
        }
    }

    #[test]
    fn frame_renders_progress_workers_and_telem() {
        let mut led = DistLedger::default();
        led.runs.push(rec("fixed:2", 0, 100.0));
        led.runs.push(rec("nacfl:1", 0, 50.0));
        led.runs.push(rec("nacfl:1", 0, 50.0)); // duplicate bits — dedup
        led.claims.insert(
            "k".into(),
            ClaimRecord::new("k", "w0", 1000, 600),
        );
        led.telem.push(TelemLine {
            scope: "campaign".into(),
            key: "w0".into(),
            metric: "exp.runs_completed".into(),
            counter: Some(2),
            hist: None,
        });
        let (frame, complete) = render_frame(&led, None, 1100);
        assert!(frame.contains("2 runs"), "dedup by key: {frame}");
        assert!(frame.contains("worker w0"), "{frame}");
        assert!(frame.contains("lease age 100s"), "{frame}");
        assert!(frame.contains("LIVE"), "{frame}");
        assert!(frame.contains("exp.runs_completed: 2"), "{frame}");
        assert!(frame.contains("homog:2|quant:inf|sim:60|sync"), "{frame}");
        assert!(frame.contains('*'), "canvas renders: {frame}");
        assert!(!complete, "no plan/header -> total unknown -> never complete");
    }

    #[test]
    fn frame_with_plan_tracks_completion_and_group_totals() {
        let plan = ExperimentPlan::builder("t")
            .policies(["fixed:2", "nacfl:1"])
            .build()
            .unwrap();
        let n = plan.n_runs();
        let mut led = DistLedger::default();
        let (frame, complete) = render_frame(&led, Some(&plan), 0);
        assert!(frame.contains(&format!("0/{n} runs")), "{frame}");
        assert!(!complete);
        for cell in plan.cells() {
            let mut r = rec(&cell.policy, cell.seed, 1.0);
            r.scenario = cell.scenario.label();
            r.compressor = cell.compressor.clone();
            r.tier = cell.tier.label();
            r.discipline = cell.discipline.label();
            r.data_seed = cell.data_seed;
            led.runs.push(r);
        }
        let (frame, complete) = render_frame(&led, Some(&plan), 0);
        assert!(frame.contains(&format!("{n}/{n} runs (100%)")), "{frame}");
        assert!(complete);
        assert!(frame.contains(&"#".repeat(BAR_W)), "full bar: {frame}");
    }

    #[test]
    fn frame_splits_fault_groups_and_rolls_up_fault_health() {
        let mut led = DistLedger::default();
        led.runs.push(rec("fixed:2", 0, 100.0));
        let mut f = rec("fixed:2", 0, 150.0);
        f.faults = "loss:0.2+deadline:40".into();
        f.retrans_s = 12.5;
        f.quorum_frac = 0.75;
        led.runs.push(f);
        let (frame, _) = render_frame(&led, None, 0);
        // Same (scenario, …, discipline) but distinct fault coordinates:
        // two separate group bars, and the key carries the spec.
        assert!(
            frame.contains("homog:2|quant:inf|sim:60|sync|loss:0.2+deadline:40"),
            "{frame}"
        );
        assert!(frame.contains("2 runs"), "fault twin is a distinct key: {frame}");
        assert!(
            frame.contains("faults: 1 run(s), retrans 1.250e1 s, mean quorum 0.750"),
            "{frame}"
        );
        // Fault-free ledgers render no fault line at all.
        let mut clean = DistLedger::default();
        clean.runs.push(rec("fixed:2", 0, 100.0));
        let (frame, _) = render_frame(&clean, None, 0);
        assert!(!frame.contains("faults:"), "{frame}");
    }

    #[test]
    fn frame_splits_pop_groups_and_rolls_up_participation() {
        let mut led = DistLedger::default();
        led.runs.push(rec("fixed:2", 0, 100.0));
        let mut p = rec("fixed:2", 0, 150.0);
        p.pop = "pop:1000000:k1000:classeshilo".into();
        p.sampled_k = 1000.0;
        p.participation = "0:812,1:188".into();
        led.runs.push(p);
        let mut p2 = rec("fixed:2", 1, 160.0);
        p2.pop = "pop:1000000:k1000:classeshilo".into();
        p2.sampled_k = 1000.0;
        p2.participation = "0:790,1:210".into();
        led.runs.push(p2);
        let (frame, _) = render_frame(&led, None, 0);
        // Distinct pop coordinates split the group bars, and the rollup
        // sums per-class participation across runs.
        assert!(
            frame.contains("homog:2|quant:inf|sim:60|sync|pop:1000000:k1000:classeshilo"),
            "{frame}"
        );
        assert!(frame.contains("pop: 2 run(s), mean sampled K 1000"), "{frame}");
        assert!(frame.contains("class0 1602"), "812+790: {frame}");
        assert!(frame.contains("class1 398"), "188+210: {frame}");
        // Pop-free ledgers render no pop line at all.
        let mut clean = DistLedger::default();
        clean.runs.push(rec("fixed:2", 0, 100.0));
        let (frame, _) = render_frame(&clean, None, 0);
        assert!(!frame.contains("pop:"), "{frame}");
    }

    #[test]
    fn frame_draws_a_level_sparkline_from_series_lines() {
        use crate::obs::{RoundSeries, Sample};
        let mut led = DistLedger::default();
        let r = rec("nacfl:1", 0, 100.0);
        let mut ser = RoundSeries::on();
        for i in 0..8 {
            ser.record(Sample { level_mean: i as f64, ..Sample::default() });
        }
        led.series.push(ser.line(&r.key()).unwrap());
        led.runs.push(r);
        let (frame, _) = render_frame(&led, None, 0);
        assert!(frame.contains("1 series"), "{frame}");
        assert!(
            frame.contains("level ▁") && frame.contains('█'),
            "ramp renders low-to-high: {frame}"
        );
        // Eight evenly spaced levels hit every ramp glyph in order.
        assert!(
            frame.contains("level ▁▂▃▄▅▆▇█ homog:2|quant:inf|sim:60|sync"),
            "sparkline sits on its group row: {frame}"
        );
        // A series line with no matching run record draws nothing.
        let mut orphan = DistLedger::default();
        let mut ser = RoundSeries::on();
        ser.record(Sample { level_mean: 1.0, ..Sample::default() });
        orphan.series.push(ser.line("no|such|run").unwrap());
        let (frame, _) = render_frame(&orphan, None, 0);
        assert!(!frame.contains("level ▁"), "{frame}");
    }

    #[test]
    fn sparkline_scales_clamps_and_skips_nan() {
        assert_eq!(sparkline(&[], 8), "");
        assert_eq!(sparkline(&[f64::NAN, f64::NAN], 8), "");
        assert_eq!(sparkline(&[5.0], 8), "▁", "flat series renders low");
        let s = sparkline(&[0.0, 7.0], 8);
        assert_eq!(s, "▁█");
        // Width keeps only the newest values.
        let long: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(sparkline(&long, 4).chars().count(), 4);
    }

    #[test]
    fn tail_survives_compaction_shrinking_the_file_underneath() {
        use crate::exp::dist::compact_ledger;
        use crate::exp::dist::ledger::PlanHeader;
        use crate::obs::{RoundSeries, Sample};
        let path = std::env::temp_dir()
            .join(format!("nacfl_top_compact_{}.jsonl", std::process::id()));
        let plan = ExperimentPlan::builder("t").build().unwrap();
        let done = rec("nacfl:1", 0, 5.0);
        let mut ser = RoundSeries::on();
        ser.record(Sample { level_mean: 2.0, ..Sample::default() });
        // Header, a superseded claim, a duplicated record, a series line.
        let body = format!(
            "{}\n{}\n{}\n{}\n{}\n",
            PlanHeader::for_plan(&plan).to_json(),
            ClaimRecord::new(done.key(), "w1", 10, 60).to_json(),
            done.to_json(),
            done.to_json(),
            ser.line(&done.key()).unwrap().to_json(),
        );
        std::fs::write(&path, &body).unwrap();
        let mut tail = LedgerTail::new();
        let led = tail.poll(&path).unwrap();
        assert_eq!(led.runs.len(), 2, "pre-compaction dup visible");
        assert_eq!(led.series.len(), 1);
        let pre = tail.cursor();

        // Compaction rewrites the file shorter; the tail must detect the
        // shrink, reset, and re-read the compacted state whole.
        compact_ledger(&path).unwrap();
        let led = tail.poll(&path).unwrap();
        assert!(tail.cursor() < pre, "compacted ledger is shorter");
        assert_eq!(led.runs.len(), 1, "dup gone after re-read");
        assert_eq!(led.series.len(), 1, "series line survives compaction");
        assert_eq!(led.claims.len(), 0, "superseded claim gone");
        assert!(led.header.is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tail_parses_only_appended_lines_and_survives_torn_tails() {
        use std::io::Write;
        let path = std::env::temp_dir()
            .join(format!("nacfl_top_tail_{}.jsonl", std::process::id()));
        let line = |r: &RunRecord| format!("{}\n", r.to_json());
        std::fs::write(&path, line(&rec("fixed:2", 0, 1.0))).unwrap();
        let mut tail = LedgerTail::new();
        assert_eq!(tail.poll(&path).unwrap().runs.len(), 1);
        let after_one = tail.cursor();
        assert!(after_one > 0);
        // Nothing appended: the cursor holds, the state is reused.
        assert_eq!(tail.poll(&path).unwrap().runs.len(), 1);
        assert_eq!(tail.cursor(), after_one);
        // A torn tail (no newline yet) is not consumed...
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        let full = line(&rec("fixed:2", 1, 2.0));
        let (head, rest) = full.split_at(10);
        f.write_all(head.as_bytes()).unwrap();
        f.flush().unwrap();
        let led = tail.poll(&path).unwrap();
        assert_eq!(led.runs.len(), 1);
        assert_eq!(led.n_torn, 0, "partial line is deferred, not counted torn");
        assert_eq!(tail.cursor(), after_one);
        // ...and ingests whole once its newline lands.
        f.write_all(rest.as_bytes()).unwrap();
        f.flush().unwrap();
        drop(f);
        let led = tail.poll(&path).unwrap();
        assert_eq!(led.runs.len(), 2);
        assert_eq!(led.runs[1].seed, 1);
        assert_eq!(tail.cursor(), after_one + full.len() as u64);
        // Truncation (compaction/rotation) resets and re-reads.
        std::fs::write(&path, line(&rec("nacfl:1", 7, 3.0))).unwrap();
        let led = tail.poll(&path).unwrap();
        assert_eq!(led.runs.len(), 1, "shrunk file -> full re-read");
        assert_eq!(led.runs[0].seed, 7);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bar_clamps_and_scales() {
        assert_eq!(bar(0, 4), format!("[{}]", "-".repeat(BAR_W)));
        assert_eq!(bar(4, 4), format!("[{}]", "#".repeat(BAR_W)));
        assert_eq!(bar(0, 0), format!("[{}]", "-".repeat(BAR_W)));
        let half = bar(2, 4);
        assert_eq!(half.matches('#').count(), BAR_W / 2);
    }
}

//! DES event-trace export: Chrome `trace_event` / Perfetto JSON
//! (DESIGN.md §16).
//!
//! Where the round series (this module's sibling, [`super::series`])
//! shows *per-round* adaptation, the trace shows *per-event* timing:
//! every client upload as a duration slice on its own track,
//! retransmissions / crashes / deadline cuts as instants, and flow-link
//! utilization as counter tracks — openable directly in
//! `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! [`TraceRecorder`] follows the platform's runtime-off handle contract
//! (`Telemetry`, `RoundSeries`): the off handle is one `None` word,
//! every method one branch, and the engines guard recording with
//! [`TraceRecorder::is_on`] so traced-off runs stay bit-identical.
//! Event storage is hard-capped at [`TRACE_EVENT_CAP`] per run — a
//! long run drops the tail (counted, surfaced as a final metadata
//! event) rather than growing without bound.
//!
//! The exporter maps simulated seconds to trace microseconds, one
//! *process* per run (named by the run's coordinate key) and one
//! *thread* per client (`tid = client + 1`; tid 0 carries round-level
//! instants and counters).  The output is the plain JSON-array flavor
//! of the trace-event format — no enclosing object needed.

use crate::util::json;
use anyhow::{Context, Result};
use std::path::Path;

/// Per-run event budget.  50k events ≈ a few MB of JSON — about what
/// the trace viewers stay responsive on.
pub const TRACE_EVENT_CAP: usize = 50_000;

/// One trace event, pre-pid (the writer assigns pids per run).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event name (slice label / counter name / instant label).
    pub name: String,
    /// Category tag (`"upload"`, `"net"`, `"fault"`).
    pub cat: &'static str,
    /// Phase: `'X'` duration, `'i'` instant, `'C'` counter.
    pub ph: char,
    /// Start, simulated microseconds.
    pub ts_us: f64,
    /// Duration, simulated microseconds (`'X'` only).
    pub dur_us: f64,
    /// Track: 0 = round/link track, `client + 1` = that client.
    pub tid: u64,
    /// Single argument (counter value, instant detail).
    pub arg: Option<(&'static str, f64)>,
}

#[derive(Clone, Debug, Default)]
struct TraceInner {
    events: Vec<TraceEvent>,
    dropped: u64,
}

/// The per-run trace recorder (runtime-off; see the module docs).
#[derive(Clone, Debug, Default)]
pub struct TraceRecorder {
    inner: Option<Box<TraceInner>>,
}

const US: f64 = 1e6;

impl TraceRecorder {
    /// The disabled handle: no allocation, every method a no-op.
    pub fn off() -> Self {
        TraceRecorder { inner: None }
    }

    /// An enabled handle.
    pub fn on() -> Self {
        TraceRecorder { inner: Some(Box::default()) }
    }

    /// Enabled (`on`) or disabled (`off`) by flag.
    pub fn new(enabled: bool) -> Self {
        if enabled {
            Self::on()
        } else {
            Self::off()
        }
    }

    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    fn push(&mut self, ev: TraceEvent) {
        if let Some(inner) = &mut self.inner {
            if inner.events.len() >= TRACE_EVENT_CAP {
                inner.dropped += 1;
            } else {
                inner.events.push(ev);
            }
        }
    }

    /// A client upload as a duration slice on the client's track.
    pub fn upload(&mut self, client: usize, start_s: f64, dur_s: f64) {
        if !self.is_on() {
            return;
        }
        self.push(TraceEvent {
            name: "upload".to_string(),
            cat: "upload",
            ph: 'X',
            ts_us: start_s * US,
            dur_us: dur_s.max(0.0) * US,
            tid: client as u64 + 1,
            arg: None,
        });
    }

    /// An instantaneous event (retransmission, crash, deadline cut) on
    /// a client's track, or on track 0 when `client` is `None`.
    pub fn instant(&mut self, name: &'static str, t_s: f64, client: Option<usize>) {
        if !self.is_on() {
            return;
        }
        self.push(TraceEvent {
            name: name.to_string(),
            cat: "fault",
            ph: 'i',
            ts_us: t_s * US,
            dur_us: 0.0,
            tid: client.map(|c| c as u64 + 1).unwrap_or(0),
            arg: None,
        });
    }

    /// One counter-track observation (e.g. `link0` utilization).  The
    /// viewer draws one counter track per distinct `name`.
    pub fn counter(&mut self, name: String, t_s: f64, key: &'static str, v: f64) {
        if !self.is_on() {
            return;
        }
        self.push(TraceEvent {
            name,
            cat: "net",
            ph: 'C',
            ts_us: t_s * US,
            dur_us: 0.0,
            tid: 0,
            arg: Some((key, if v.is_finite() { v } else { 0.0 })),
        });
    }

    /// Events recorded so far.
    pub fn events(&self) -> &[TraceEvent] {
        self.inner.as_ref().map(|i| i.events.as_slice()).unwrap_or(&[])
    }

    /// Events discarded past [`TRACE_EVENT_CAP`].
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map(|i| i.dropped).unwrap_or(0)
    }
}

/// A non-finite-safe trace number (the format has no NaN literal).
fn tnum(v: f64) -> String {
    json::num(if v.is_finite() { v } else { 0.0 })
}

fn event_json(ev: &TraceEvent, pid: usize) -> String {
    let mut out = format!(
        "{{\"name\":{},\"cat\":{},\"ph\":\"{}\",\"ts\":{},\"pid\":{},\"tid\":{}",
        json::string(&ev.name),
        json::string(ev.cat),
        ev.ph,
        tnum(ev.ts_us),
        pid,
        ev.tid,
    );
    if ev.ph == 'X' {
        out.push_str(&format!(",\"dur\":{}", tnum(ev.dur_us)));
    }
    if ev.ph == 'i' {
        // Thread-scoped instant (the viewer default needs an explicit
        // scope to render off-track instants).
        out.push_str(",\"s\":\"t\"");
    }
    if let Some((k, v)) = &ev.arg {
        out.push_str(&format!(",\"args\":{{\"{k}\":{}}}", tnum(*v)));
    }
    out.push('}');
    out
}

/// Write one Chrome `trace_event` JSON-array file for a set of traced
/// runs: process `i + 1` is run `i`, named by its coordinate key via a
/// `process_name` metadata event.  Runs with no events still get their
/// metadata row, so an empty trace is still a valid, openable file.
pub fn write_trace_file(
    path: impl AsRef<Path>,
    runs: &[(String, TraceRecorder)],
) -> Result<()> {
    let path = path.as_ref();
    let mut out = String::from("[");
    let mut first = true;
    let mut push = |line: String, out: &mut String, first: &mut bool| {
        if !*first {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&line);
        *first = false;
    };
    for (i, (key, rec)) in runs.iter().enumerate() {
        let pid = i + 1;
        push(
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"tid\":0,\"args\":{{\"name\":{}}}}}",
                pid,
                json::string(key),
            ),
            &mut out,
            &mut first,
        );
        for ev in rec.events() {
            push(event_json(ev, pid), &mut out, &mut first);
        }
        if rec.dropped() > 0 {
            push(
                format!(
                    "{{\"name\":\"trace_dropped_events\",\"ph\":\"M\",\"pid\":{},\"tid\":0,\"args\":{{\"name\":\"dropped {}\"}}}}",
                    pid,
                    rec.dropped(),
                ),
                &mut out,
                &mut first,
            );
        }
    }
    out.push_str("\n]\n");
    std::fs::write(path, out)
        .with_context(|| format!("writing trace file {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_is_a_no_op_and_allocation_free() {
        let mut t = TraceRecorder::off();
        assert!(!t.is_on());
        t.upload(3, 1.0, 2.0);
        t.instant("crash", 5.0, Some(1));
        t.counter("link0".into(), 1.0, "util", 0.5);
        assert!(t.events().is_empty());
        assert_eq!(t.dropped(), 0);
        assert!(std::mem::size_of::<TraceRecorder>() <= std::mem::size_of::<usize>());
    }

    #[test]
    fn events_serialize_as_trace_event_json() {
        let mut t = TraceRecorder::on();
        t.upload(0, 1.5, 0.25);
        t.instant("deadline", 2.0, None);
        t.counter("link0".into(), 2.0, "util", 0.75);
        let path = std::env::temp_dir()
            .join(format!("nacfl_trace_{}.json", std::process::id()));
        write_trace_file(&path, &[("run|key".to_string(), t)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let trimmed = text.trim();
        assert!(trimmed.starts_with('[') && trimmed.ends_with(']'), "{text}");
        assert!(text.contains("\"ph\":\"M\"") && text.contains("run|key"), "{text}");
        assert!(
            text.contains("\"ph\":\"X\"") && text.contains("\"dur\":250000.0"),
            "{text}"
        );
        assert!(text.contains("\"ph\":\"i\"") && text.contains("\"s\":\"t\""), "{text}");
        assert!(
            text.contains("\"ph\":\"C\"") && text.contains("\"args\":{\"util\":0.75}"),
            "{text}"
        );
        // Upload lands on the client track, counter on track 0.
        assert!(text.contains("\"tid\":1"), "{text}");
        // Balanced braces — the file parses as one JSON array.
        let opens = text.matches('{').count();
        assert_eq!(opens, text.matches('}').count());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn event_cap_drops_the_tail_not_the_run() {
        let mut t = TraceRecorder::on();
        for i in 0..(TRACE_EVENT_CAP + 10) {
            t.upload(i % 8, i as f64, 0.5);
        }
        assert_eq!(t.events().len(), TRACE_EVENT_CAP);
        assert_eq!(t.dropped(), 10);
        let path = std::env::temp_dir()
            .join(format!("nacfl_trace_cap_{}.json", std::process::id()));
        write_trace_file(&path, &[("k".to_string(), t)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("dropped 10"), "drop count is surfaced");
        std::fs::remove_file(&path).ok();
    }
}

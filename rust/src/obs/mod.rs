//! Observability: counters, log-bucket histograms, spans, and the
//! `"kind":"telem"` ledger line (DESIGN.md §12).
//!
//! The paper's objective is *wall-clock training time*, but until this
//! module the platform recorded exactly one end-of-run scalar per cell.
//! [`Telemetry`] is a zero-dependency, allocation-conscious handle
//! threaded through the four hot layers (`des::engine`, `policy::
//! solver`, `sim::Session`, `exp::exec`/`exp::dist`):
//!
//! * **counters** — monotone `u64` sums under `&'static str` names
//!   (`des.events_popped`, `exp.runs_completed`, …) plus max-gauges
//!   (`des.queue_high_water`);
//! * **histograms** — fixed 64-bucket base-2 log histograms
//!   ([`Histogram`]): bucket `i` covers `[2^(i-32), 2^(i-31))`, so one
//!   array spans nanoseconds to days with no configuration and no
//!   allocation;
//! * **spans** — [`Telemetry::span_begin`]/[`Telemetry::span_end`]
//!   measure monotonic wall time (ns) into a histogram per span name;
//!   [`Telemetry::sim_span`] records *simulated*-seconds durations the
//!   same way (the engines' per-round breakdown).
//!
//! The handle is **runtime-off by default**: [`Telemetry::off`] holds no
//! allocation and every method is one branch on a `None` — the engines
//! keep their bit-identical, allocation-free hot paths (pinned by
//! `tests/obs_system.rs`).  When enabled, per-run aggregates stream into
//! the campaign ledger as flat `"kind":"telem"` JSONL lines
//! ([`TelemLine`]) which the resume/merge machinery ignores by
//! construction (every reader dispatches on `"kind"`), and `nacfl top` /
//! `nacfl report` (this module's [`top`] / [`report`]) read them back.

pub mod report;
pub mod series;
pub mod top;
pub mod trace;

pub use series::{RoundSeries, Sample, SeriesLine, SERIES_CAP};
pub use trace::{write_trace_file, TraceRecorder, TRACE_EVENT_CAP};

use crate::util::json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::time::Instant;

/// Number of log-2 buckets in a [`Histogram`].  Bucket `i` covers
/// `[2^(i-32), 2^(i-31))`; NaN, negative, zero and sub-`2^-32` values
/// land in bucket 0, values `>= 2^31` (including `+inf`) clamp into the
/// last bucket.
pub const N_BUCKETS: usize = 64;

/// Allocation-free log-2 bucket histogram (count / sum / min / max +
/// fixed bucket array).  `#[derive(Default)]` would zero min/max, so the
/// empty state is constructed explicitly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub buckets: [u64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; N_BUCKETS],
        }
    }
}

/// The bucket index for a value: `floor(log2(v)) + 32`, clamped to the
/// array.  Total for every `f64`: NaN and non-positive values go to
/// bucket 0, `+inf` clamps into the last bucket like any over-range
/// value — no input can panic or index out of bounds.
pub fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= 0.0 {
        return 0;
    }
    if v == f64::INFINITY {
        return N_BUCKETS - 1;
    }
    (v.log2().floor() as i64 + 32).clamp(0, N_BUCKETS as i64 - 1) as usize
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.count = self.count.saturating_add(1);
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let b = bucket_of(v);
        self.buckets[b] = self.buckets[b].saturating_add(1);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Fold another histogram into this one (report aggregation across
    /// ledgers / workers).  Counts saturate instead of overflowing —
    /// merged fleet histograms must never take the reader down.
    pub fn merge(&mut self, other: &Histogram) {
        self.count = self.count.saturating_add(other.count);
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a = a.saturating_add(*b);
        }
    }

    /// Sparse `"idx:count,idx:count"` form (the ledger is flat JSON, so
    /// the bucket array travels as one string).
    fn buckets_compact(&self) -> String {
        let mut out = String::new();
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if !out.is_empty() {
                out.push(',');
            }
            out.push_str(&format!("{i}:{c}"));
        }
        out
    }

    fn from_compact(s: &str) -> Result<[u64; N_BUCKETS]> {
        let mut buckets = [0u64; N_BUCKETS];
        if s.is_empty() {
            return Ok(buckets);
        }
        for part in s.split(',') {
            let (i, c) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("bad bucket entry `{part}`"))?;
            let i: usize = i.parse().map_err(|e| anyhow!("bad bucket index `{i}`: {e}"))?;
            if i >= N_BUCKETS {
                return Err(anyhow!("bucket index {i} out of range"));
            }
            buckets[i] = c.parse().map_err(|e| anyhow!("bad bucket count `{c}`: {e}"))?;
        }
        Ok(buckets)
    }
}

/// Everything a live handle tracks.  Kept behind a `Box` so the
/// off-state [`Telemetry`] is a single `None` word.
#[derive(Clone, Debug, Default)]
struct Inner {
    counters: Vec<(&'static str, u64)>,
    maxima: Vec<(&'static str, u64)>,
    hists: Vec<(&'static str, Histogram)>,
    /// Open wall-clock spans, LIFO.
    open: Vec<(&'static str, Instant)>,
    /// `span_end` calls that did not match the innermost open span.
    mismatches: u64,
}

fn bump(table: &mut Vec<(&'static str, u64)>, name: &'static str, delta: u64, max: bool) {
    for (k, v) in table.iter_mut() {
        if *k == name {
            // Saturating: a runaway counter pins at u64::MAX instead of
            // panicking (debug) or wrapping to a lie (release).
            *v = if max { (*v).max(delta) } else { v.saturating_add(delta) };
            return;
        }
    }
    table.push((name, delta));
}

/// The telemetry handle.  [`Telemetry::off`] is free to construct and
/// every method on it is a no-op; [`Telemetry::on`] allocates one inner
/// block and small name-keyed tables (linear scan — the metric
/// namespace is a few dozen static names, not a registry).
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    inner: Option<Box<Inner>>,
}

impl Telemetry {
    /// The disabled handle: no allocation, every method a no-op.
    pub fn off() -> Self {
        Telemetry { inner: None }
    }

    /// An enabled handle.
    pub fn on() -> Self {
        Telemetry { inner: Some(Box::default()) }
    }

    /// Enabled (`on`) or disabled (`off`) by flag.
    pub fn new(enabled: bool) -> Self {
        if enabled {
            Self::on()
        } else {
            Self::off()
        }
    }

    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    /// Add `delta` to the counter `name`.
    pub fn count(&mut self, name: &'static str, delta: u64) {
        if let Some(inner) = &mut self.inner {
            bump(&mut inner.counters, name, delta, false);
        }
    }

    /// Track the maximum of `v` seen under `name` (queue high-water
    /// marks and the like; serialized as a counter line).
    pub fn gauge_max(&mut self, name: &'static str, v: u64) {
        if let Some(inner) = &mut self.inner {
            bump(&mut inner.maxima, name, v, true);
        }
    }

    /// Record `v` into the histogram `name`.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            hist_mut(&mut inner.hists, name).observe(v);
        }
    }

    /// Open a monotonic-clock span.  Spans nest LIFO; the elapsed
    /// nanoseconds are recorded into the histogram `name` on the
    /// matching [`Telemetry::span_end`].
    pub fn span_begin(&mut self, name: &'static str) {
        if let Some(inner) = &mut self.inner {
            inner.open.push((name, Instant::now()));
        }
    }

    /// Close the innermost open span.  A `name` that does not match the
    /// innermost span (or an empty stack) increments a mismatch counter
    /// instead of panicking — telemetry must never take the engine down.
    pub fn span_end(&mut self, name: &'static str) {
        if let Some(inner) = &mut self.inner {
            match inner.open.last() {
                Some((open_name, _)) if *open_name == name => {
                    let (_, t0) = inner.open.pop().unwrap();
                    let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as f64;
                    hist_mut(&mut inner.hists, name).observe(ns);
                }
                _ => inner.mismatches += 1,
            }
        }
    }

    /// Record a *simulated-time* span: `seconds` of simulated wall time
    /// attributed to `name` (one histogram observation).
    pub fn sim_span(&mut self, name: &'static str, seconds: f64) {
        self.observe(name, seconds);
    }

    /// Current value of a counter (0 when off / never bumped).
    pub fn counter(&self, name: &str) -> u64 {
        let Some(inner) = &self.inner else { return 0 };
        inner
            .counters
            .iter()
            .chain(inner.maxima.iter())
            .find(|(k, _)| *k == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// The histogram under `name`, if any value was observed.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.inner
            .as_ref()?
            .hists
            .iter()
            .find(|(k, h)| *k == name && h.count > 0)
            .map(|(_, h)| h)
    }

    /// Mismatched `span_end` calls (0 means the span nesting was clean).
    pub fn span_mismatches(&self) -> u64 {
        self.inner.as_ref().map(|i| i.mismatches).unwrap_or(0)
    }

    /// Export every non-empty metric as a [`TelemLine`] under the given
    /// scope/key (insertion order — deterministic for a deterministic
    /// engine flow).  Still-open spans are NOT flushed; `span.open` and
    /// `span.mismatch` counters surface bookkeeping errors instead.
    pub fn lines(&self, scope: &str, key: &str) -> Vec<TelemLine> {
        let Some(inner) = &self.inner else { return Vec::new() };
        let mut out = Vec::new();
        let mk = |metric: &str| TelemLine {
            scope: scope.to_string(),
            key: key.to_string(),
            metric: metric.to_string(),
            counter: None,
            hist: None,
        };
        for (name, v) in inner.counters.iter().chain(inner.maxima.iter()) {
            let mut l = mk(name);
            l.counter = Some(*v);
            out.push(l);
        }
        if inner.mismatches > 0 {
            let mut l = mk("obs.span_mismatch");
            l.counter = Some(inner.mismatches);
            out.push(l);
        }
        if !inner.open.is_empty() {
            let mut l = mk("obs.span_open");
            l.counter = Some(inner.open.len() as u64);
            out.push(l);
        }
        for (name, h) in &inner.hists {
            if h.count == 0 {
                continue;
            }
            let mut l = mk(name);
            l.hist = Some(*h);
            out.push(l);
        }
        out
    }
}

fn hist_mut<'a>(
    table: &'a mut Vec<(&'static str, Histogram)>,
    name: &'static str,
) -> &'a mut Histogram {
    if let Some(i) = table.iter().position(|(k, _)| *k == name) {
        return &mut table[i].1;
    }
    table.push((name, Histogram::default()));
    &mut table.last_mut().unwrap().1
}

/// One flat `"kind":"telem"` ledger line: a counter or a histogram
/// snapshot, scoped to a run (key = the run's coordinate key) or to the
/// whole campaign (key = worker id).  Schema-versioned alongside the
/// ledger (`"schema":2`, `"v":1`); every ledger reader dispatches on
/// `"kind"` first, so telem lines are invisible to resume/merge keying.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemLine {
    /// `"run"` or `"campaign"`.
    pub scope: String,
    /// Run coordinate key, or worker id for campaign scope.
    pub key: String,
    /// Dotted metric name (`des.events_popped`, `solver.solve_ns`, …).
    pub metric: String,
    /// Counter value (`"type":"counter"` lines).
    pub counter: Option<u64>,
    /// Histogram snapshot (`"type":"hist"` lines).
    pub hist: Option<Histogram>,
}

impl TelemLine {
    /// One flat JSON object (a single ledger line, no trailing newline).
    /// Floats use the shared shortest-round-trip policy (`util::json`),
    /// so `from_json(to_json(x)) == x` byte-for-byte.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"schema\":2,\"kind\":\"telem\",\"v\":1,\"scope\":{},\"key\":{},\"metric\":{}",
            json::string(&self.scope),
            json::string(&self.key),
            json::string(&self.metric),
        );
        if let Some(h) = &self.hist {
            out.push_str(&format!(
                ",\"type\":\"hist\",\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\"buckets\":{}",
                h.count,
                json::num(h.sum),
                json::num(h.min),
                json::num(h.max),
                json::string(&h.buckets_compact()),
            ));
        } else {
            out.push_str(&format!(
                ",\"type\":\"counter\",\"value\":{}",
                self.counter.unwrap_or(0)
            ));
        }
        out.push('}');
        out
    }

    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_obj(&crate::exp::sink::parse_flat_object(line)?)
    }

    /// Build from an already-scanned flat object (shared with the
    /// distributed-ledger line dispatcher, `exp::dist::ledger`).
    pub(crate) fn from_obj(
        obj: &HashMap<String, crate::exp::sink::JsonVal>,
    ) -> Result<Self> {
        use crate::exp::sink::JsonVal;
        let s = |k: &str| -> Result<String> {
            obj.get(k)
                .and_then(JsonVal::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("telem line missing string field `{k}`"))
        };
        let u = |k: &str| -> Result<u64> {
            obj.get(k)
                .and_then(JsonVal::as_u64)
                .ok_or_else(|| anyhow!("telem line field `{k}` must be a non-negative integer"))
        };
        if obj.get("kind").and_then(JsonVal::as_str) != Some("telem") {
            return Err(anyhow!("not a telem line"));
        }
        match obj.get("v").and_then(JsonVal::as_u64) {
            Some(1) => {}
            other => return Err(anyhow!("unsupported telem line version {other:?}")),
        }
        let mut line = TelemLine {
            scope: s("scope")?,
            key: s("key")?,
            metric: s("metric")?,
            counter: None,
            hist: None,
        };
        match obj.get("type").and_then(JsonVal::as_str) {
            Some("counter") => line.counter = Some(u("value")?),
            Some("hist") => {
                let n = |k: &str| -> Result<f64> {
                    match obj.get(k) {
                        Some(JsonVal::Num(v)) => Ok(*v),
                        Some(JsonVal::Null) => Ok(f64::NAN),
                        _ => Err(anyhow!("telem line missing numeric field `{k}`")),
                    }
                };
                line.hist = Some(Histogram {
                    count: u("count")?,
                    sum: n("sum")?,
                    min: n("min")?,
                    max: n("max")?,
                    buckets: Histogram::from_compact(&s("buckets")?)?,
                });
            }
            other => return Err(anyhow!("unsupported telem line type {other:?}")),
        }
        Ok(line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_covers_powers_of_two_and_clamps() {
        // Bucket i covers [2^(i-32), 2^(i-31)).
        assert_eq!(bucket_of(1.0), 32);
        assert_eq!(bucket_of(1.5), 32);
        assert_eq!(bucket_of(2.0), 33);
        assert_eq!(bucket_of(0.5), 31);
        assert_eq!(bucket_of(0.75), 31);
        // Degenerate inputs land in a bucket instead of panicking:
        // non-positive and NaN in bucket 0, +inf clamped to the top.
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(-0.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(f64::NEG_INFINITY), 0);
        assert_eq!(bucket_of(f64::INFINITY), N_BUCKETS - 1);
        // Clamped at both ends.
        assert_eq!(bucket_of(1e-300), 0);
        assert_eq!(bucket_of(1e300), N_BUCKETS - 1);
        assert_eq!(bucket_of(f64::MIN_POSITIVE), 0);
        assert_eq!(bucket_of(f64::MAX), N_BUCKETS - 1);
        // Nanosecond-scale span values stay well inside the array.
        assert_eq!(bucket_of(1e9), 61);
    }

    #[test]
    fn counts_saturate_instead_of_overflowing() {
        // Counter near the ceiling: one more bump must pin, not wrap.
        let mut t = Telemetry::on();
        t.count("c", u64::MAX - 1);
        t.count("c", 5);
        assert_eq!(t.counter("c"), u64::MAX);
        t.count("c", 1);
        assert_eq!(t.counter("c"), u64::MAX, "stays pinned");

        // Histogram merge with both counts near the ceiling.
        let mut a = Histogram::default();
        a.observe(1.0);
        a.count = u64::MAX - 1;
        a.buckets[32] = u64::MAX - 1;
        let mut b = Histogram::default();
        b.observe(1.0);
        b.count = 7;
        b.buckets[32] = 7;
        a.merge(&b);
        assert_eq!(a.count, u64::MAX);
        assert_eq!(a.buckets[32], u64::MAX);

        // observe() at the ceiling saturates too.
        let mut h = Histogram::default();
        h.count = u64::MAX;
        h.buckets[32] = u64::MAX;
        h.observe(1.0);
        assert_eq!(h.count, u64::MAX);
        assert_eq!(h.buckets[32], u64::MAX);
    }

    #[test]
    fn degenerate_observations_stay_in_range() {
        // +inf is observable (clamps into the top bucket); NaN is
        // ignored; negatives land in bucket 0 — nothing panics.
        let mut h = Histogram::default();
        h.observe(f64::INFINITY);
        assert_eq!(h.count, 1);
        assert_eq!(h.buckets[N_BUCKETS - 1], 1);
        h.observe(f64::NAN);
        assert_eq!(h.count, 1, "NaN is not an observation");
        h.observe(-2.0);
        assert_eq!(h.count, 2);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.min, -2.0);
    }

    #[test]
    fn histogram_tracks_count_sum_min_max() {
        let mut h = Histogram::default();
        for v in [1.0, 4.0, 0.25] {
            h.observe(v);
        }
        h.observe(f64::NAN); // ignored
        assert_eq!(h.count, 3);
        assert_eq!(h.sum, 5.25);
        assert_eq!(h.min, 0.25);
        assert_eq!(h.max, 4.0);
        assert_eq!(h.buckets[32], 1);
        assert_eq!(h.buckets[34], 1);
        assert_eq!(h.buckets[30], 1);
        let mut other = Histogram::default();
        other.observe(4.0);
        h.merge(&other);
        assert_eq!(h.count, 4);
        assert_eq!(h.buckets[34], 2);
    }

    #[test]
    fn off_handle_is_a_no_op_and_allocation_free() {
        let mut t = Telemetry::off();
        assert!(!t.is_on());
        t.count("x", 3);
        t.observe("y", 1.0);
        t.span_begin("z");
        t.span_end("z");
        t.sim_span("w", 2.0);
        assert_eq!(t.counter("x"), 0);
        assert!(t.histogram("y").is_none());
        assert!(t.lines("run", "k").is_empty());
        // The off handle is one Option word — nothing boxed.
        assert!(std::mem::size_of::<Telemetry>() <= std::mem::size_of::<usize>());
    }

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut t = Telemetry::on();
        t.count("a", 1);
        t.count("a", 2);
        t.count("b", 5);
        t.gauge_max("hw", 3);
        t.gauge_max("hw", 9);
        t.gauge_max("hw", 4);
        assert_eq!(t.counter("a"), 3);
        assert_eq!(t.counter("b"), 5);
        assert_eq!(t.counter("hw"), 9);
        assert_eq!(t.counter("missing"), 0);
    }

    #[test]
    fn spans_nest_lifo_and_mismatches_are_counted_not_fatal() {
        let mut t = Telemetry::on();
        t.span_begin("outer");
        t.span_begin("inner");
        t.span_end("inner");
        t.span_end("outer");
        assert_eq!(t.span_mismatches(), 0);
        let inner = t.histogram("inner").unwrap();
        let outer = t.histogram("outer").unwrap();
        assert_eq!(inner.count, 1);
        assert_eq!(outer.count, 1);
        assert!(outer.min >= inner.min * 0.0, "spans record non-negative ns");

        // Ending a span that is not the innermost one must not panic,
        // must not record, and must be visible in the mismatch counter.
        t.span_begin("a");
        t.span_end("not-a");
        assert_eq!(t.span_mismatches(), 1);
        t.span_end("a");
        assert_eq!(t.span_mismatches(), 1);
        t.span_end("a"); // empty stack
        assert_eq!(t.span_mismatches(), 2);
        let lines = t.lines("run", "k");
        assert!(lines
            .iter()
            .any(|l| l.metric == "obs.span_mismatch" && l.counter == Some(2)));
    }

    #[test]
    fn telem_lines_round_trip_through_util_json() {
        let mut t = Telemetry::on();
        t.count("des.events_popped", 123);
        t.gauge_max("des.queue_high_water", 17);
        t.observe("solver.solve_ns", 1500.0);
        t.observe("solver.solve_ns", 64.0);
        t.sim_span("sim.round_s", 2.5);
        let lines = t.lines("run", "homog:2|quant:inf|sim:60|sync|nacfl:1|0|0");
        assert_eq!(lines.len(), 4);
        for line in &lines {
            let text = line.to_json();
            let back = TelemLine::from_json(&text).unwrap();
            assert_eq!(&back, line, "parse must invert serialization");
            assert_eq!(back.to_json(), text, "byte-stable round trip");
        }
        // Spot-check the wire shape of one counter and one hist line.
        let counter = &lines[0];
        let text = counter.to_json();
        assert!(text.contains("\"kind\":\"telem\""), "{text}");
        assert!(text.contains("\"type\":\"counter\""), "{text}");
        assert!(text.contains("\"value\":123"), "{text}");
        let hist = lines.iter().find(|l| l.hist.is_some()).unwrap();
        let text = hist.to_json();
        assert!(text.contains("\"type\":\"hist\""), "{text}");
        assert!(text.contains("\"count\":2"), "{text}");
        assert!(text.contains("\"buckets\":\""), "{text}");
    }

    #[test]
    fn telem_from_json_rejects_malformed_lines() {
        assert!(TelemLine::from_json("").is_err());
        assert!(TelemLine::from_json("{\"kind\":\"claim\"}").is_err(), "wrong kind");
        let good = TelemLine {
            scope: "run".into(),
            key: "k".into(),
            metric: "m".into(),
            counter: Some(1),
            hist: None,
        }
        .to_json();
        assert!(TelemLine::from_json(&good).is_ok());
        assert!(TelemLine::from_json(&good[..good.len() / 2]).is_err(), "torn line");
        let v2 = good.replace("\"v\":1", "\"v\":2");
        assert!(TelemLine::from_json(&v2).is_err(), "future telem version");
        let bad_buckets = TelemLine {
            scope: "run".into(),
            key: "k".into(),
            metric: "m".into(),
            counter: None,
            hist: Some(Histogram::default()),
        };
        let text = bad_buckets.to_json().replace("\"buckets\":\"\"", "\"buckets\":\"99:x\"");
        assert!(TelemLine::from_json(&text).is_err(), "bad bucket entry");
    }
}

//! Population model: million-client rosters with per-round cohort
//! sampling (the standard cross-device FL shape).
//!
//! A `pop:<N>:k<K>[:classes<preset-or-path>]` plan axis describes a
//! client population of size N partitioned into weighted **classes**
//! with heterogeneous log-normal BTD marginals (compute+link speed
//! tiers).  Every round samples K distinct participants from the
//! population on a coordinate-pure stream — `Rng::new(seed).
//! derive("pop-sample", fnv1a(label))`, mirroring the fault-stream
//! contract — so ledgers are byte-identical across threads and shards.
//!
//! Scale contract: nothing here is ever O(N) per round.  Class
//! membership of client `i` is a *pure function* of `i` (index ranges at
//! the cumulative mixture weights, [`PopSpec::class_of`]), cohort
//! sampling is Floyd's O(K) algorithm ([`sample_k_of_n`]), and the
//! struct-of-arrays cohort state ([`CohortProcess`]) is materialized
//! lazily for the K sampled slots only.  The DES engines see a plain
//! [`NetworkProcess`] of dimension K, so every discipline, fault
//! channel, policy and compressor composes unchanged; under `flow:`
//! scenarios the sampled cohort is admitted behind the preset's shared
//! links (the flow engine sizes its network from `dim()`).
//!
//! Scenario composition at population scale (DESIGN.md §15): `homog` /
//! `heterog` / `flow` cells draw purely idiosyncratic per-slot BTDs
//! from the class marginals; `perf:si2` / `part:si2` multiply every
//! slot by a *common* scalar AR(1) log-factor (Table-III `a`) — the
//! rank-1 approximation of the paper's correlated scenarios, the only
//! form with O(1) cross-round state at N = 10^6.

use crate::netsim::{NetworkProcess, ScenarioKind};
use crate::util::rng::{fnv1a, Rng};
use anyhow::{anyhow, Context, Result};

/// Hard cap on class count: per-class telemetry counters need static
/// names (`pop.class0` … `pop.class7`).
pub const MAX_CLASSES: usize = 8;

/// Static telemetry counter names, one per class slot.
pub const CLASS_COUNTERS: [&str; MAX_CLASSES] = [
    "pop.class0",
    "pop.class1",
    "pop.class2",
    "pop.class3",
    "pop.class4",
    "pop.class5",
    "pop.class6",
    "pop.class7",
];

/// One population class: mixture weight + log-normal BTD marginal
/// (`c = exp(N(mu, sigma^2))`, the paper's §IV-A2 form).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientClass {
    pub weight: f64,
    pub mu: f64,
    pub sigma: f64,
}

/// Parsed `pop:<N>:k<K>[:classes<preset-or-path>]` population spec.
#[derive(Clone, Debug, PartialEq)]
pub struct PopSpec {
    /// Population size N.
    pub n: u64,
    /// Sampled cohort size K per round.
    pub k: usize,
    /// Class-set name: `uniform` (default), `hilo`, `mobile`, or a file
    /// path (recognized by a `/` or a `.toml` suffix).
    pub classes: String,
    /// Resolved classes (weights normalized to sum 1).
    pub class_set: Vec<ClientClass>,
    /// Cumulative class boundaries scaled to N: client `i` belongs to
    /// the first class `c` with `i < bounds[c]`; `bounds.last() == n`.
    bounds: Vec<u64>,
}

impl PopSpec {
    /// Parse a `pop:<N>:k<K>[:classes<preset-or-path>]` spec.  Class
    /// files are plain text, one `weight mu sigma` triple per line
    /// (`#` comments); presets are `uniform | hilo | mobile`.
    pub fn parse(s: &str) -> Result<Self> {
        const USAGE: &str = "pop:<N>:k<K>[:classes<uniform|hilo|mobile|path>]";
        let rest = s
            .strip_prefix("pop:")
            .ok_or_else(|| anyhow!("population spec must start with `pop:` ({USAGE})"))?;
        // The classes argument may itself contain `:` (paths), so split
        // at most twice.
        let mut parts = rest.splitn(3, ':');
        let n: u64 = parts
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|e| anyhow!("population size N: {e} ({USAGE})"))?;
        if n == 0 {
            return Err(anyhow!("population size N must be >= 1"));
        }
        let karg = parts.next().ok_or_else(|| anyhow!("missing k<K> argument ({USAGE})"))?;
        let k: usize = karg
            .strip_prefix('k')
            .ok_or_else(|| anyhow!("second argument must be k<K>, got `{karg}` ({USAGE})"))?
            .parse()
            .map_err(|e| anyhow!("cohort size K: {e} ({USAGE})"))?;
        if k == 0 || k as u64 > n {
            return Err(anyhow!("cohort size K must be in 1..=N, got {k} of {n}"));
        }
        let classes = match parts.next() {
            None => "uniform".to_string(),
            Some(c) => c
                .strip_prefix("classes")
                .ok_or_else(|| anyhow!("third argument must be classes<...>, got `{c}` ({USAGE})"))?
                .to_string(),
        };
        if classes.is_empty() {
            return Err(anyhow!("empty class-set name ({USAGE})"));
        }
        let class_set = resolve_classes(&classes)?;
        let bounds = class_bounds(&class_set, n);
        Ok(PopSpec { n, k, classes, class_set, bounds })
    }

    /// Canonical label (round-trips through [`PopSpec::parse`]); the
    /// default `uniform` class set is omitted, so pre-pop ledger keys
    /// never grow spurious suffixes.
    pub fn label(&self) -> String {
        if self.classes == "uniform" {
            format!("pop:{}:k{}", self.n, self.k)
        } else {
            format!("pop:{}:k{}:classes{}", self.n, self.k, self.classes)
        }
    }

    /// Class index of client `i` — a pure function of `i`, O(log C),
    /// never O(N) state.
    pub fn class_of(&self, i: u64) -> usize {
        debug_assert!(i < self.n);
        self.bounds.partition_point(|&b| b <= i)
    }

    /// The coordinate-pure sampling stream for one experiment cell:
    /// seed + spec label, independent of thread count and shard split
    /// (the `fault_stream_id` contract).
    pub fn sample_stream(&self, seed: u64) -> Rng {
        Rng::new(seed).derive("pop-sample", fnv1a(self.label().as_bytes()))
    }
}

impl std::fmt::Display for PopSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

fn resolve_classes(name: &str) -> Result<Vec<ClientClass>> {
    let raw = match name {
        "uniform" => vec![ClientClass { weight: 1.0, mu: 1.0, sigma: 1.0 }],
        // Fast majority + slow tail (the hi/lo device split).
        "hilo" => vec![
            ClientClass { weight: 0.8, mu: 0.8, sigma: 0.8 },
            ClientClass { weight: 0.2, mu: 2.0, sigma: 1.2 },
        ],
        // wifi / cellular / edge device mix.
        "mobile" => vec![
            ClientClass { weight: 0.5, mu: 0.7, sigma: 0.6 },
            ClientClass { weight: 0.35, mu: 1.2, sigma: 1.0 },
            ClientClass { weight: 0.15, mu: 2.5, sigma: 1.4 },
        ],
        path if path.contains('/') || path.ends_with(".toml") => parse_class_file(path)?,
        other => {
            return Err(anyhow!(
                "unknown class set `{other}` (uniform | hilo | mobile | a file path)"
            ))
        }
    };
    if raw.is_empty() {
        return Err(anyhow!("class set must define at least one class"));
    }
    if raw.len() > MAX_CLASSES {
        return Err(anyhow!("at most {MAX_CLASSES} classes supported, got {}", raw.len()));
    }
    let total: f64 = raw.iter().map(|c| c.weight).sum();
    if !total.is_finite() || total <= 0.0 {
        return Err(anyhow!("class weights must be positive and finite"));
    }
    for c in &raw {
        if !(c.weight > 0.0 && c.weight.is_finite()) {
            return Err(anyhow!("class weight must be positive and finite, got {}", c.weight));
        }
        if !c.mu.is_finite() || !c.sigma.is_finite() || c.sigma < 0.0 {
            return Err(anyhow!("class (mu, sigma) must be finite with sigma >= 0"));
        }
    }
    Ok(raw.iter().map(|c| ClientClass { weight: c.weight / total, ..*c }).collect())
}

/// Text class file: one `weight mu sigma` triple per whitespace-split
/// line, `#` starts a comment.
fn parse_class_file(path: &str) -> Result<Vec<ClientClass>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading population class file {path}"))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let nums: Vec<f64> = line
            .split_whitespace()
            .map(|t| t.parse().map_err(|e| anyhow!("{path}:{}: {e}", lineno + 1)))
            .collect::<Result<_>>()?;
        if nums.len() != 3 {
            return Err(anyhow!(
                "{path}:{}: expected `weight mu sigma`, got {} field(s)",
                lineno + 1,
                nums.len()
            ));
        }
        out.push(ClientClass { weight: nums[0], mu: nums[1], sigma: nums[2] });
    }
    Ok(out)
}

/// Cumulative class boundaries scaled to N (monotone, last == N).
fn class_bounds(classes: &[ClientClass], n: u64) -> Vec<u64> {
    let mut bounds = Vec::with_capacity(classes.len());
    let mut cum = 0.0;
    for (c, cl) in classes.iter().enumerate() {
        cum += cl.weight;
        let b = if c + 1 == classes.len() {
            n
        } else {
            ((cum * n as f64).round() as u64).min(n)
        };
        let prev = bounds.last().copied().unwrap_or(0);
        bounds.push(b.max(prev));
    }
    bounds
}

/// Sample K distinct indices from `0..n` into `out` (ascending) with
/// Floyd's algorithm: exactly K RNG draws, O(K) time and space — never
/// O(N).  The ascending sort makes the cohort order a pure function of
/// the sampled *set* (hash-iteration order never leaks into ledgers).
pub fn sample_k_of_n(rng: &mut Rng, n: u64, k: usize, out: &mut Vec<u64>) {
    debug_assert!(k as u64 <= n && k > 0);
    out.clear();
    let mut seen = std::collections::HashSet::with_capacity(k * 2);
    for j in (n - k as u64)..n {
        let t = rng.below((j + 1) as usize) as u64;
        if seen.insert(t) {
            out.push(t);
        } else {
            seen.insert(j);
            out.push(j);
        }
    }
    out.sort_unstable();
}

/// Common cross-client AR(1) log-factor for the correlated scenarios
/// (rank-1 approximation; O(1) state).
#[derive(Clone, Debug)]
struct CommonFactor {
    a: f64,
    scale: f64,
    z: f64,
    rng: Rng,
}

/// The sampled-cohort network process: a [`NetworkProcess`] of
/// dimension K whose every `next_state` (a) resamples the cohort from
/// the population, (b) materializes struct-of-arrays state (`indices`,
/// `slot_class`) for the K slots only, and (c) returns per-slot BTDs
/// from the class marginals.  The DES engines treat slot `j` as a
/// client; fault channels (dropout/loss/crash/stragglers) therefore act
/// on cohort *slots* — the documented population-scale approximation
/// (a per-client crash ledger would be O(N) state).
pub struct CohortProcess {
    pub spec: PopSpec,
    sample_rng: Rng,
    common: Option<CommonFactor>,
    /// Sampled population indices, ascending (slot -> client id).
    pub indices: Vec<u64>,
    /// Class of each cohort slot.
    pub slot_class: Vec<u8>,
    /// Rounds sampled so far.
    pub rounds: u64,
    /// Per-class participation counts across all rounds.
    pub participation: [u64; MAX_CLASSES],
}

impl CohortProcess {
    /// Build the cell's cohort process: sampling on the coordinate-pure
    /// `pop-sample` stream, and (for `perf`/`part` scenarios) the
    /// common congestion factor on an independent `pop-net` stream.
    pub fn new(spec: PopSpec, scenario: ScenarioKind, seed: u64) -> Result<Self> {
        let common = match scenario {
            ScenarioKind::PerfectlyCorrelated { sigma_inf_sq }
            | ScenarioKind::PartiallyCorrelated { sigma_inf_sq } => {
                let a = crate::netsim::Ar1Process::a_for_asymptotic_variance(sigma_inf_sq);
                // part: only half the per-client variance is common
                // (Sigma_ij = 1/2), so the shared factor is damped.
                let scale = if matches!(scenario, ScenarioKind::PartiallyCorrelated { .. }) {
                    0.5f64.sqrt()
                } else {
                    1.0
                };
                Some(CommonFactor { a, scale, z: 0.0, rng: Rng::new(seed).derive("pop-net", 0) })
            }
            // homog/heterog/flow: purely idiosyncratic class marginals
            // (flow cells get their shared-link coupling from the flow
            // engine itself, not from the BTD process).
            _ => None,
        };
        let sample_rng = spec.sample_stream(seed);
        let k = spec.k;
        Ok(CohortProcess {
            spec,
            sample_rng,
            common,
            indices: Vec::with_capacity(k),
            slot_class: Vec::with_capacity(k),
            rounds: 0,
            participation: [0; MAX_CLASSES],
        })
    }

    /// Total sampled (client, round) pairs so far: K * rounds.
    pub fn sampled_total(&self) -> u64 {
        self.spec.k as u64 * self.rounds
    }

    /// Compact `class:count` participation summary for the run record
    /// (`0:123,1:456`; classes with zero participation omitted).
    pub fn participation_label(&self) -> String {
        let mut out = String::new();
        for (c, &cnt) in self.participation.iter().enumerate() {
            if cnt == 0 {
                continue;
            }
            if !out.is_empty() {
                out.push(',');
            }
            out.push_str(&format!("{c}:{cnt}"));
        }
        out
    }
}

impl NetworkProcess for CohortProcess {
    fn dim(&self) -> usize {
        self.spec.k
    }

    /// Mean class index of the *current* cohort (the round-series
    /// `cohort_mix` channel); NaN before the first round.
    fn cohort_mix(&self) -> f64 {
        if self.slot_class.is_empty() {
            return f64::NAN;
        }
        self.slot_class.iter().map(|&c| c as f64).sum::<f64>() / self.slot_class.len() as f64
    }

    fn next_state(&mut self) -> Vec<f64> {
        self.rounds += 1;
        sample_k_of_n(&mut self.sample_rng, self.spec.n, self.spec.k, &mut self.indices);
        let zf = match &mut self.common {
            Some(cf) => {
                cf.z = cf.a * cf.z + cf.rng.normal();
                (cf.z * cf.scale).exp()
            }
            None => 1.0,
        };
        self.slot_class.clear();
        let mut c = Vec::with_capacity(self.spec.k);
        for s in 0..self.indices.len() {
            let cls = self.spec.class_of(self.indices[s]);
            self.slot_class.push(cls as u8);
            self.participation[cls] += 1;
            let cc = self.spec.class_set[cls];
            c.push(self.sample_rng.normal_ms(cc.mu, cc.sigma).exp() * zf);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_round_trip() {
        let p = PopSpec::parse("pop:1000000:k1000").unwrap();
        assert_eq!(p.n, 1_000_000);
        assert_eq!(p.k, 1000);
        assert_eq!(p.classes, "uniform");
        assert_eq!(p.label(), "pop:1000000:k1000");
        assert_eq!(PopSpec::parse(&p.label()).unwrap(), p);

        let p = PopSpec::parse("pop:5000:k64:classeshilo").unwrap();
        assert_eq!(p.class_set.len(), 2);
        assert_eq!(p.label(), "pop:5000:k64:classeshilo");
        assert_eq!(PopSpec::parse(&p.label()).unwrap(), p);

        // The default class set canonicalizes away.
        let p = PopSpec::parse("pop:100:k10:classesuniform").unwrap();
        assert_eq!(p.label(), "pop:100:k10");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "pop",
            "pop:0:k1",
            "pop:100",
            "pop:100:10",
            "pop:100:k0",
            "pop:100:k101",
            "pop:100:k5:hilo",
            "pop:100:k5:classes",
            "pop:100:k5:classesnope",
            "pop:x:k5",
        ] {
            assert!(PopSpec::parse(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn class_file_parses_weight_mu_sigma_lines() {
        let dir = std::env::temp_dir().join("nacfl_pop_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("classes.toml");
        std::fs::write(&path, "# fleet\n3 0.5 0.6\n1 2.0 1.0 # slow\n").unwrap();
        let spec = PopSpec::parse(&format!("pop:1000:k10:classes{}", path.display())).unwrap();
        assert_eq!(spec.class_set.len(), 2);
        assert!((spec.class_set[0].weight - 0.75).abs() < 1e-12, "weights normalize");
        assert!((spec.class_set[1].mu - 2.0).abs() < 1e-12);
        assert!(PopSpec::parse("pop:1000:k10:classes/no/such/file").is_err());
    }

    #[test]
    fn class_of_follows_mixture_bounds() {
        let spec = PopSpec::parse("pop:1000:k10:classeshilo").unwrap();
        // hilo = 0.8 / 0.2 -> boundary at 800.
        assert_eq!(spec.class_of(0), 0);
        assert_eq!(spec.class_of(799), 0);
        assert_eq!(spec.class_of(800), 1);
        assert_eq!(spec.class_of(999), 1);
    }

    #[test]
    fn floyd_sampling_is_k_distinct_sorted_and_deterministic() {
        let mut rng = Rng::new(3).derive("pop-sample", 1);
        let mut a = Vec::new();
        sample_k_of_n(&mut rng, 1_000_000, 1000, &mut a);
        assert_eq!(a.len(), 1000);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "ascending + distinct");
        assert!(a.iter().all(|&i| i < 1_000_000));
        let mut rng2 = Rng::new(3).derive("pop-sample", 1);
        let mut b = Vec::new();
        sample_k_of_n(&mut rng2, 1_000_000, 1000, &mut b);
        assert_eq!(a, b, "same stream -> same cohort");
        // k == n degenerates to the full roster.
        let mut full = Vec::new();
        sample_k_of_n(&mut rng, 10, 10, &mut full);
        assert_eq!(full, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn cohort_process_materializes_k_slots_and_counts_participation() {
        let spec = PopSpec::parse("pop:10000:k50:classesmobile").unwrap();
        let scen = ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 };
        let mut p = CohortProcess::new(spec, scen, 7).unwrap();
        assert_eq!(p.dim(), 50);
        for _ in 0..20 {
            let c = p.next_state();
            assert_eq!(c.len(), 50);
            assert!(c.iter().all(|&x| x > 0.0));
            assert_eq!(p.indices.len(), 50);
            assert_eq!(p.slot_class.len(), 50);
        }
        assert_eq!(p.rounds, 20);
        assert_eq!(p.sampled_total(), 1000);
        assert_eq!(p.participation.iter().sum::<u64>(), 1000);
        // All three mobile classes should appear in 1000 draws.
        assert!(p.participation[..3].iter().all(|&x| x > 0), "{:?}", p.participation);
        let label = p.participation_label();
        assert!(label.starts_with("0:"), "{label}");
        assert_eq!(label.split(',').count(), 3);
        // cohort_mix: mean class index of the current cohort, in range.
        let mix = p.cohort_mix();
        assert!(mix.is_finite() && (0.0..3.0).contains(&mix), "mix {mix}");
    }

    #[test]
    fn participation_tracks_mixture_weights() {
        let spec = PopSpec::parse("pop:100000:k200:classeshilo").unwrap();
        let scen = ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 };
        let mut p = CohortProcess::new(spec, scen, 11).unwrap();
        for _ in 0..200 {
            p.next_state();
        }
        let total = p.participation.iter().sum::<u64>() as f64;
        let frac0 = p.participation[0] as f64 / total;
        assert!((frac0 - 0.8).abs() < 0.02, "class-0 frac {frac0} vs weight 0.8");
    }

    #[test]
    fn correlated_scenarios_share_a_common_factor() {
        let spec = PopSpec::parse("pop:1000:k100").unwrap();
        let scen = ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: 4.0 };
        let mut hi = 0usize;
        let mut p = CohortProcess::new(spec, scen, 5).unwrap();
        // With a shared factor the per-round mean log-BTD should move
        // together: measure cross-round variance of the round means and
        // require it to exceed the idiosyncratic-only baseline.
        let spec2 = PopSpec::parse("pop:1000:k100").unwrap();
        let mut q =
            CohortProcess::new(spec2, ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 }, 5)
                .unwrap();
        let round_mean = |c: &[f64]| c.iter().map(|x| x.ln()).sum::<f64>() / c.len() as f64;
        let mut vp = Vec::new();
        let mut vq = Vec::new();
        for _ in 0..200 {
            vp.push(round_mean(&p.next_state()));
            vq.push(round_mean(&q.next_state()));
        }
        let var = |v: &[f64]| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64
        };
        if var(&vp) > 4.0 * var(&vq) {
            hi += 1;
        }
        assert_eq!(hi, 1, "common factor must dominate round-mean variance");
    }

    #[test]
    fn sampling_stream_is_coordinate_pure() {
        let spec = PopSpec::parse("pop:1000:k10").unwrap();
        let a = spec.sample_stream(3).next_u64();
        let b = spec.sample_stream(3).next_u64();
        assert_eq!(a, b);
        // Different seed or different spec -> different stream.
        assert_ne!(a, spec.sample_stream(4).next_u64());
        let other = PopSpec::parse("pop:1000:k20").unwrap();
        assert_ne!(a, other.sample_stream(3).next_u64());
    }
}

//! Leader <-> worker wire types.

use std::sync::Arc;

/// Work order for one client for one round.
#[derive(Clone, Debug)]
pub struct RoundWork {
    pub round: usize,
    /// Broadcast global model (shared, read-only).
    pub w: Arc<Vec<f32>>,
    pub eta: f32,
    /// This client's chosen bit-width.
    pub bits: u8,
}

/// Worker -> leader response.
#[derive(Clone, Debug)]
pub enum WorkerMsg {
    /// Quantized (dequantized-view) update ready for aggregation.
    Update {
        client: usize,
        round: usize,
        dq: Vec<f32>,
        norm: f32,
    },
    /// Injected failure: the update was lost in transit.
    Dropped { client: usize, round: usize },
    /// Unrecoverable worker error (engine failure).
    Fatal { client: usize, error: String },
}

impl WorkerMsg {
    pub fn client(&self) -> usize {
        match self {
            WorkerMsg::Update { client, .. }
            | WorkerMsg::Dropped { client, .. }
            | WorkerMsg::Fatal { client, .. } => *client,
        }
    }

    pub fn round(&self) -> Option<usize> {
        match self {
            WorkerMsg::Update { round, .. } | WorkerMsg::Dropped { round, .. } => Some(*round),
            WorkerMsg::Fatal { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let m = WorkerMsg::Dropped { client: 3, round: 9 };
        assert_eq!(m.client(), 3);
        assert_eq!(m.round(), Some(9));
        let f = WorkerMsg::Fatal { client: 1, error: "x".into() };
        assert_eq!(f.round(), None);
    }
}

//! Client worker: owns a private compute engine, a data shard, and the
//! client's RNG streams; executes local rounds + quantization on demand.
//!
//! Streams are derived with the same labels as the sequential reference
//! (`batch`/`quant` keyed by client id), so the threaded pipeline
//! reproduces it bit-for-bit.

use super::messages::{RoundWork, WorkerMsg};
use crate::data::Dataset;
use crate::fl::engine::{make_engine, ComputeEngine};
use crate::quant::levels;
use crate::util::rng::Rng;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Per-worker failure-injection knobs (see `leader::FailureConfig`).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerFaults {
    /// Probability an update is dropped after compute.
    pub drop_prob: f64,
    /// Artificial straggler delay per round (coordination latency, not
    /// simulated wall clock).
    pub straggle: Option<std::time::Duration>,
}

pub struct WorkerSpec {
    pub id: usize,
    pub engine_kind: String,
    pub artifact_dir: String,
    pub train: Arc<Dataset>,
    pub shard: Vec<usize>,
    pub seed: u64,
    pub tau: usize,
    pub batch: usize,
    pub faults: WorkerFaults,
}

/// Worker thread body: loop over work orders until the channel closes.
pub fn run_worker(spec: WorkerSpec, rx: Receiver<RoundWork>, tx: Sender<WorkerMsg>) {
    let root = Rng::new(spec.seed);
    let mut batch_rng = root.derive("batch", spec.id as u64);
    let mut quant_rng = root.derive("quant", spec.id as u64);
    let mut fault_rng = root.derive("fault", spec.id as u64);

    let mut engine: Box<dyn ComputeEngine> =
        match make_engine(&spec.engine_kind, &spec.artifact_dir) {
            Ok(e) => e,
            Err(e) => {
                let _ = tx.send(WorkerMsg::Fatal { client: spec.id, error: e.to_string() });
                return;
            }
        };
    let dims = engine.dims();
    let mut uniforms = vec![0.0f32; dims.p];

    while let Ok(work) = rx.recv() {
        // Sample tau stacked minibatches from this client's shard.
        let mut xs = Vec::with_capacity(spec.tau * spec.batch * spec.train.dim);
        let mut ys = Vec::with_capacity(spec.tau * spec.batch);
        for _ in 0..spec.tau {
            for _ in 0..spec.batch {
                let i = spec.shard[batch_rng.below(spec.shard.len())];
                xs.extend_from_slice(spec.train.image(i));
                ys.push(spec.train.labels[i] as i32);
            }
        }

        let result = engine
            .local_round(&work.w, &xs, &ys, work.eta)
            .and_then(|upd| {
                quant_rng.fill_uniform_f32(&mut uniforms);
                engine.quantize(&upd, levels(work.bits), &uniforms)
            });

        if let Some(d) = spec.faults.straggle {
            std::thread::sleep(d);
        }

        let msg = match result {
            Ok((dq, norm)) => {
                // Fault path consumes randomness AFTER compute so the
                // fault-free stream matches the sequential reference.
                if spec.faults.drop_prob > 0.0 && fault_rng.uniform() < spec.faults.drop_prob {
                    WorkerMsg::Dropped { client: spec.id, round: work.round }
                } else {
                    WorkerMsg::Update { client: spec.id, round: work.round, dq, norm }
                }
            }
            Err(e) => WorkerMsg::Fatal { client: spec.id, error: e.to_string() },
        };
        if tx.send(msg).is_err() {
            return; // leader gone
        }
    }
}

//! Leader: the FL server event loop.
//!
//! Owns the compression policy, the network-state observation, the
//! global model, evaluation, metrics and the simulated wall clock; farms
//! the per-client local stage + quantization out to worker threads and
//! aggregates at a round barrier (in client order, for bit-exact parity
//! with the sequential reference loop).

use super::messages::{RoundWork, WorkerMsg};
use super::worker::{run_worker, WorkerFaults, WorkerSpec};
use crate::config::ExperimentConfig;
use crate::data::{Dataset, Partition};
use crate::fl::engine::{make_engine, ComputeEngine};
use crate::fl::fedcom::evaluate;
use crate::metrics::{RunTrace, TracePoint};
use crate::model::{Mlp, MlpDims};
use crate::netsim::NetworkProcess;
use crate::policy::CompressionPolicy;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Coordinator-level failure injection (tests + robustness benches).
#[derive(Clone, Debug, Default)]
pub struct FailureConfig {
    /// Per-round update drop probability, per client.
    pub drop_prob: f64,
    /// Straggler injection: (client id, artificial latency).
    pub straggler: Option<(usize, std::time::Duration)>,
}

/// Per-client state for the inline (single-threaded) execution mode.
/// §Perf L3-3: on a 1-core host, 10 worker threads each owning a PJRT
/// CPU client thrash the scheduler (measured 845 ms/round vs 69 ms
/// sequential); when the resolved worker count is 1 the leader runs the
/// identical per-client computation inline with the same RNG streams,
/// so results stay bit-identical to the threaded mode.
struct InlineClients {
    engine: Box<dyn ComputeEngine>,
    shards: Vec<Vec<usize>>,
    batch_rngs: Vec<Rng>,
    quant_rngs: Vec<Rng>,
    fault_rngs: Vec<Rng>,
    drop_prob: f64,
}

pub struct Coordinator {
    cfg: ExperimentConfig,
    train: Arc<Dataset>,
    test: Arc<Dataset>,
    seed: u64,
    eval_engine: Box<dyn ComputeEngine>,
    work_txs: Vec<mpsc::Sender<RoundWork>>,
    result_rx: Option<mpsc::Receiver<WorkerMsg>>,
    handles: Vec<JoinHandle<()>>,
    inline: Option<InlineClients>,
    /// Rounds in which at least one update was dropped (diagnostics).
    pub degraded_rounds: Vec<usize>,
}

impl Coordinator {
    pub fn new(
        cfg: &ExperimentConfig,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
        part: &Partition,
        seed: u64,
        faults: &FailureConfig,
    ) -> Result<Self> {
        let m = cfg.m;
        if part.m() != m {
            return Err(anyhow!("partition has {} clients, config wants {m}", part.m()));
        }
        let compressor = crate::quant::parse_compressor(&cfg.compressor, &cfg.compressor_env())
            .map_err(|e| anyhow!("invalid compressor spec `{}`: {e}", cfg.compressor))?;
        if !compressor.spec().starts_with("quant") {
            return Err(anyhow!(
                "the ML tier's AOT quantizer kernels implement the `quant:inf` compressor \
                 only; got `{}` (run other families on the analytic/DES tiers)",
                cfg.compressor
            ));
        }
        let eval_engine = make_engine(&cfg.engine, &cfg.artifact_dir)?;

        // Resolve the worker count: 0 = auto (threads only when the host
        // actually has parallelism to exploit — §Perf L3-3).
        let resolved_workers = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        if resolved_workers <= 1 {
            let root = Rng::new(seed);
            let inline = InlineClients {
                engine: make_engine(&cfg.engine, &cfg.artifact_dir)?,
                shards: (0..m).map(|j| part.client(j).to_vec()).collect(),
                batch_rngs: (0..m).map(|j| root.derive("batch", j as u64)).collect(),
                quant_rngs: (0..m).map(|j| root.derive("quant", j as u64)).collect(),
                fault_rngs: (0..m).map(|j| root.derive("fault", j as u64)).collect(),
                drop_prob: faults.drop_prob,
            };
            return Ok(Coordinator {
                cfg: cfg.clone(),
                train,
                test,
                seed,
                eval_engine,
                work_txs: Vec::new(),
                result_rx: None,
                handles: Vec::new(),
                inline: Some(inline),
                degraded_rounds: Vec::new(),
            });
        }

        let (result_tx, result_rx) = mpsc::channel::<WorkerMsg>();
        let mut work_txs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for j in 0..m {
            let (tx, rx) = mpsc::channel::<RoundWork>();
            work_txs.push(tx);
            let spec = WorkerSpec {
                id: j,
                engine_kind: cfg.engine.clone(),
                artifact_dir: cfg.artifact_dir.clone(),
                train: Arc::clone(&train),
                shard: part.client(j).to_vec(),
                seed,
                tau: cfg.tau,
                batch: cfg.batch,
                faults: WorkerFaults {
                    drop_prob: faults.drop_prob,
                    straggle: faults
                        .straggler
                        .and_then(|(id, d)| (id == j).then_some(d)),
                },
            };
            let rtx = result_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("nacfl-worker-{j}"))
                    .spawn(move || run_worker(spec, rx, rtx))
                    .map_err(|e| anyhow!("spawn worker {j}: {e}"))?,
            );
        }
        drop(result_tx);
        Ok(Coordinator {
            cfg: cfg.clone(),
            train,
            test,
            seed,
            eval_engine,
            work_txs,
            result_rx: Some(result_rx),
            handles,
            inline: None,
            degraded_rounds: Vec::new(),
        })
    }

    /// True when running in the single-threaded inline mode.
    pub fn is_inline(&self) -> bool {
        self.inline.is_some()
    }

    /// Inline-mode client stage: identical math + RNG streams as
    /// `worker::run_worker`, executed on the leader thread.
    fn inline_round(
        inline: &mut InlineClients,
        train: &Dataset,
        w: &[f32],
        eta: f32,
        bits: &[u8],
        slots: &mut [Option<Vec<f32>>],
        tau: usize,
        batch: usize,
    ) -> Result<()> {
        let m = bits.len();
        let d = inline.engine.dims();
        let mut uniforms = vec![0.0f32; d.p];
        for j in 0..m {
            let shard = &inline.shards[j];
            let mut xs = Vec::with_capacity(tau * batch * train.dim);
            let mut ys = Vec::with_capacity(tau * batch);
            for _ in 0..tau {
                for _ in 0..batch {
                    let i = shard[inline.batch_rngs[j].below(shard.len())];
                    xs.extend_from_slice(train.image(i));
                    ys.push(train.labels[i] as i32);
                }
            }
            let upd = inline.engine.local_round(w, &xs, &ys, eta)?;
            inline.quant_rngs[j].fill_uniform_f32(&mut uniforms);
            let (dq, _norm) =
                inline
                    .engine
                    .quantize(&upd, crate::quant::levels(bits[j]), &uniforms)?;
            // Fault stream consumed after compute — parity with workers.
            slots[j] = if inline.drop_prob > 0.0
                && inline.fault_rngs[j].uniform() < inline.drop_prob
            {
                None
            } else {
                Some(dq)
            };
        }
        Ok(())
    }

    /// Drive training to the target accuracy (or max_rounds).
    pub fn run(
        &mut self,
        policy: &mut dyn CompressionPolicy,
        process: &mut dyn NetworkProcess,
    ) -> Result<RunTrace> {
        let cfg = &self.cfg;
        let ctx = cfg.policy_ctx();
        let m = cfg.m;
        let root = Rng::new(self.seed);
        let mlp = Mlp::new(MlpDims::paper());
        let mut w = Arc::new(mlp.init_params(&mut root.derive("init", 0)));

        let mut eval_rng = root.derive("eval", 0);
        let test_idx =
            eval_rng.sample_indices(self.test.len(), cfg.eval_samples.min(self.test.len()));
        let train_idx = eval_rng
            .sample_indices(self.train.len(), cfg.train_eval_samples.min(self.train.len()));

        let mut trace = RunTrace::new(&policy.name(), &cfg.scenario.label(), self.seed);
        let mut wall = 0.0f64;
        let p = self.eval_engine.dims().p;
        let mut slots: Vec<Option<Vec<f32>>> = vec![None; m];

        for n in 1..=cfg.max_rounds {
            let c = process.next_state();
            let choices = policy.choose(&ctx, &c);
            let bits: Vec<u8> = choices.iter().map(|x| x.level).collect();
            let eta = cfg.eta(n) as f32;

            for slot in slots.iter_mut() {
                *slot = None;
            }
            if let Some(inline) = self.inline.as_mut() {
                // Inline mode: run the client stage on this thread.
                Self::inline_round(
                    inline, &self.train, &w, eta, &bits, &mut slots, cfg.tau, cfg.batch,
                )?;
            } else {
                // Broadcast work orders.
                for j in 0..m {
                    self.work_txs[j]
                        .send(RoundWork { round: n, w: Arc::clone(&w), eta, bits: bits[j] })
                        .map_err(|_| anyhow!("worker {j} hung up"))?;
                }
                // Aggregation barrier: wait for all m responses.
                let rx = self.result_rx.as_ref().unwrap();
                let mut received = 0usize;
                while received < m {
                    match rx.recv() {
                        Ok(WorkerMsg::Update { client, round, dq, .. }) => {
                            debug_assert_eq!(round, n);
                            slots[client] = Some(dq);
                            received += 1;
                        }
                        Ok(WorkerMsg::Dropped { .. }) => {
                            received += 1;
                        }
                        Ok(WorkerMsg::Fatal { client, error }) => {
                            return Err(anyhow!("worker {client} failed: {error}"));
                        }
                        Err(_) => return Err(anyhow!("all workers disconnected")),
                    }
                }
            }
            let delivered = slots.iter().filter(|s| s.is_some()).count();
            if delivered < m {
                self.degraded_rounds.push(n);
            }
            if delivered > 0 {
                // Reduce in client order (bit-exact parity with fl::fedcom).
                let mut agg = vec![0.0f32; p];
                let inv = 1.0f32 / delivered as f32;
                for dq in slots.iter().flatten() {
                    for (a, &v) in agg.iter_mut().zip(dq.iter()) {
                        *a += v * inv;
                    }
                }
                let w_next =
                    self.eval_engine
                        .global_step(&w, &agg, (cfg.eta(n) * cfg.gamma) as f32)?;
                w = Arc::new(w_next);
            }
            // Every update lost: the model freezes but time is still paid.
            wall += ctx.duration(&choices, &c);

            if n % cfg.eval_every == 0 || n == cfg.max_rounds {
                let (train_loss, _) =
                    evaluate(self.eval_engine.as_mut(), &w, &self.train, &train_idx)?;
                let (_, test_acc) =
                    evaluate(self.eval_engine.as_mut(), &w, &self.test, &test_idx)?;
                trace.push(TracePoint {
                    round: n,
                    wall,
                    train_loss,
                    test_acc,
                    mean_bits: bits.iter().map(|&b| b as f64).sum::<f64>() / m as f64,
                });
                if test_acc >= cfg.target_acc {
                    break;
                }
            }
        }
        Ok(trace)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Closing the work channels terminates the workers.
        self.work_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::data::{partition, PartitionKind};
    use crate::netsim::Scenario;
    use crate::policy::parse_policy;

    fn setup() -> (ExperimentConfig, Arc<Dataset>, Arc<Dataset>, Partition) {
        let mut cfg = ExperimentConfig::smoke();
        cfg.max_rounds = 12;
        cfg.eval_every = 4;
        cfg.target_acc = 2.0;
        let train = Arc::new(generate(cfg.train_n, cfg.data_seed, &SynthConfig::default()));
        let test = Arc::new(generate(cfg.test_n, cfg.data_seed ^ 1, &SynthConfig::default()));
        let part = partition(&train, cfg.m, PartitionKind::Heterogeneous, 0);
        (cfg, train, test, part)
    }

    #[test]
    fn threaded_run_produces_trace() {
        let (cfg, train, test, part) = setup();
        let mut co =
            Coordinator::new(&cfg, train, test, &part, 1, &FailureConfig::default()).unwrap();
        let mut policy = parse_policy("nacfl").unwrap();
        let mut proc = Scenario::new(cfg.scenario, cfg.m).process(Rng::new(2)).unwrap();
        let trace = co.run(policy.as_mut(), &mut proc).unwrap();
        assert_eq!(trace.points.len(), 3);
        assert!(trace.points.last().unwrap().wall > 0.0);
        assert!(co.degraded_rounds.is_empty());
    }

    #[test]
    fn survives_dropped_updates() {
        let (cfg, train, test, part) = setup();
        let faults = FailureConfig { drop_prob: 0.4, straggler: None };
        let mut co = Coordinator::new(&cfg, train, test, &part, 1, &faults).unwrap();
        let mut policy = parse_policy("fixed:2").unwrap();
        let mut proc = Scenario::new(cfg.scenario, cfg.m).process(Rng::new(3)).unwrap();
        let trace = co.run(policy.as_mut(), &mut proc).unwrap();
        assert_eq!(trace.points.len(), 3, "training completes despite drops");
        assert!(!co.degraded_rounds.is_empty(), "drops must actually occur");
    }

    #[test]
    fn survives_straggler() {
        let (cfg, train, test, part) = setup();
        let faults = FailureConfig {
            drop_prob: 0.0,
            straggler: Some((0, std::time::Duration::from_millis(5))),
        };
        let mut co = Coordinator::new(&cfg, train, test, &part, 1, &faults).unwrap();
        let mut policy = parse_policy("fixed:1").unwrap();
        let mut proc = Scenario::new(cfg.scenario, cfg.m).process(Rng::new(4)).unwrap();
        let trace = co.run(policy.as_mut(), &mut proc).unwrap();
        assert_eq!(trace.points.len(), 3);
    }
}

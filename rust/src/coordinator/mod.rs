//! The L3 coordinator: leader/worker round pipeline.
//!
//! The leader owns the policy engine, the congestion observation, the
//! global model and the simulated wall clock; one worker thread per
//! client owns a private compute engine (its own PJRT client for the XLA
//! path) plus its data shard and RNG streams.  A round is a broadcast of
//! [`messages::RoundWork`] followed by an aggregation barrier over
//! [`messages::WorkerMsg`]; updates are reduced in client order so the
//! parallel loop is bit-identical to the sequential reference
//! (`fl::fedcom`) — enforced by the `coordinator_parity` integration
//! test.  Failure injection (update drops, stragglers) exercises the
//! barrier's degraded paths.

pub mod leader;
pub mod messages;
pub mod worker;

pub use leader::{Coordinator, FailureConfig};
pub use messages::{RoundWork, WorkerMsg};

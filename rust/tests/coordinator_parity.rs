//! Integration: the threaded coordinator reproduces the sequential
//! reference loop bit-for-bit, and its degraded paths hold invariants.

use nacfl::config::ExperimentConfig;
use nacfl::coordinator::{Coordinator, FailureConfig};
use nacfl::data::synth::{generate, SynthConfig};
use nacfl::data::{partition, Dataset, PartitionKind};
use nacfl::fl::engine::RustEngine;
use nacfl::fl::fedcom::{run_fedcom, FedcomOptions};
use nacfl::metrics::RunTrace;
use nacfl::netsim::Scenario;
use nacfl::policy::parse_policy;
use nacfl::util::rng::Rng;
use std::sync::Arc;

fn setup(max_rounds: usize) -> (ExperimentConfig, Arc<Dataset>, Arc<Dataset>) {
    let mut cfg = ExperimentConfig::smoke();
    cfg.max_rounds = max_rounds;
    cfg.eval_every = 5;
    cfg.target_acc = 2.0; // run to the cap
    let train = Arc::new(generate(cfg.train_n, cfg.data_seed, &SynthConfig::default()));
    let test = Arc::new(generate(cfg.test_n, cfg.data_seed ^ 1, &SynthConfig::default()));
    (cfg, train, test)
}

fn run_sequential(
    cfg: &ExperimentConfig,
    train: &Dataset,
    test: &Dataset,
    seed: u64,
    policy_spec: &str,
) -> RunTrace {
    let part = partition(train, cfg.m, PartitionKind::Heterogeneous, 0);
    let mut policy = parse_policy(policy_spec).unwrap();
    let mut proc = Scenario::new(cfg.scenario, cfg.m)
        .process(Rng::new(seed).derive("net", 0))
        .unwrap();
    let mut engine = RustEngine::new();
    run_fedcom(
        cfg,
        train,
        test,
        &part,
        policy.as_mut(),
        &mut proc,
        &mut engine,
        seed,
        &FedcomOptions::default(),
    )
    .unwrap()
}

fn run_threaded(
    cfg: &ExperimentConfig,
    train: &Arc<Dataset>,
    test: &Arc<Dataset>,
    seed: u64,
    policy_spec: &str,
    faults: &FailureConfig,
) -> (RunTrace, Vec<usize>) {
    let part = partition(train, cfg.m, PartitionKind::Heterogeneous, 0);
    let mut policy = parse_policy(policy_spec).unwrap();
    let mut proc = Scenario::new(cfg.scenario, cfg.m)
        .process(Rng::new(seed).derive("net", 0))
        .unwrap();
    let mut co =
        Coordinator::new(cfg, Arc::clone(train), Arc::clone(test), &part, seed, faults).unwrap();
    let trace = co.run(policy.as_mut(), &mut proc).unwrap();
    let degraded = co.degraded_rounds.clone();
    (trace, degraded)
}

#[test]
fn threaded_coordinator_is_bit_identical_to_sequential() {
    let (cfg, train, test) = setup(15);
    for policy in ["nacfl", "fixed:2", "error:5.25"] {
        let seq = run_sequential(&cfg, &train, &test, 11, policy);
        let (par, degraded) =
            run_threaded(&cfg, &train, &test, 11, policy, &FailureConfig::default());
        assert!(degraded.is_empty());
        assert_eq!(seq.points.len(), par.points.len(), "{policy}: trace length");
        for (a, b) in seq.points.iter().zip(par.points.iter()) {
            assert_eq!(a.round, b.round);
            assert_eq!(a.wall.to_bits(), b.wall.to_bits(), "{policy}: wall clock");
            assert_eq!(
                a.test_acc.to_bits(),
                b.test_acc.to_bits(),
                "{policy}: accuracy at round {}",
                a.round
            );
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{policy}: loss at round {}",
                a.round
            );
        }
    }
}

#[test]
fn wall_clock_is_policy_independent_noise_but_identical_network_path() {
    // Different policies on the same seed must see the same congestion
    // path: their round-1 durations must be in the exact ratio of the
    // file sizes they chose.  (Sample-path pairing for the gain metric.)
    let (mut cfg, train, test) = setup(5);
    cfg.eval_every = 1;
    let (t1, _) = run_threaded(&cfg, &train, &test, 3, "fixed:1", &FailureConfig::default());
    let (t2, _) = run_threaded(&cfg, &train, &test, 3, "fixed:2", &FailureConfig::default());
    let r = t2.points[0].wall / t1.points[0].wall;
    let size = nacfl::quant::SizeModel::new(nacfl::runtime::dims::P);
    let expect = size.bits(2) / size.bits(1);
    assert!(
        (r - expect).abs() < 1e-9,
        "duration ratio {r} vs size ratio {expect}"
    );
}

#[test]
fn drops_do_not_stall_and_are_recorded() {
    let (cfg, train, test) = setup(10);
    let faults = FailureConfig { drop_prob: 0.5, straggler: None };
    let (trace, degraded) = run_threaded(&cfg, &train, &test, 7, "fixed:1", &faults);
    assert_eq!(trace.points.last().unwrap().round, 10);
    assert!(!degraded.is_empty());
    // Monotone wall clock even across degraded rounds.
    let mut prev = 0.0;
    for p in &trace.points {
        assert!(p.wall >= prev);
        prev = p.wall;
    }
}

#[test]
fn total_drop_rounds_skip_model_update_but_advance_time() {
    let (mut cfg, train, test) = setup(4);
    cfg.eval_every = 1;
    let faults = FailureConfig { drop_prob: 1.0, straggler: None };
    let (trace, degraded) = run_threaded(&cfg, &train, &test, 9, "fixed:1", &faults);
    assert_eq!(degraded.len(), 4, "every round degraded");
    assert!(trace.points.last().unwrap().wall > 0.0, "time still advances");
    // Model never moved: accuracy identical across evals.
    let accs: Vec<f64> = trace.points.iter().map(|p| p.test_acc).collect();
    assert!(accs.windows(2).all(|w| w[0] == w[1]), "model should be frozen: {accs:?}");
}

//! System tests for the population subsystem and the calendar-queue
//! scheduler (ISSUE-9):
//!
//! * the timing wheel pops **bit-identically** to the retained
//!   binary-heap reference on round-shaped workloads (clustered batch
//!   arrivals, heavy ties, semi-sync cancellations), at the queue level
//!   and through the DES engine (`DesConfig::with_scheduler`);
//! * a plan with no pop axis and a plan with an explicit
//!   `pop = ["none"]` axis share a plan hash and produce byte-identical,
//!   pop-field-free ledgers (the pre-population byte shape);
//! * pop campaigns double-run to byte-identical ledgers, keep record
//!   bits across thread counts, split evenly across `--shard i/n` by
//!   cohort size, and merge bit-identically to a solo run — cohort
//!   sampling is coordinate-pure, never schedule-bound;
//! * per-class participation in the ledger tracks the class mixture
//!   weights, and a million-client cell stays O(K) per round.

use nacfl::config::ExperimentConfig;
use nacfl::des::{simulate_des, DesConfig, Discipline, EventQueue, SchedulerKind};
use nacfl::exp::{execute, merge_ledgers, ExecOptions, ExperimentPlan, ShardSpec, Tier};
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::pop::{CohortProcess, PopSpec};
use nacfl::util::rng::Rng;

const K_EPS: f64 = 60.0;

fn temp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nacfl_pop_sys_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn small_base() -> ExperimentConfig {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..2).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    base
}

fn opts_for(ledger: &str, threads: usize) -> ExecOptions {
    ExecOptions {
        threads,
        ledger: Some(ledger.to_string()),
        ..Default::default()
    }
}

/// Queue-level wheel/heap parity on the DES event shape: rounds push
/// batches of quantized (tie-heavy) arrival times, pops interleave with
/// pushes, and semi-sync cancellations clear mid-stream.  The pop
/// sequences must match entry for entry, through several wheel resizes.
#[test]
fn schedulers_agree_on_round_shaped_workloads() {
    let mut rng = Rng::new(0x90F);
    let mut wheel = EventQueue::with_kind(SchedulerKind::Wheel);
    let mut heap = EventQueue::with_kind(SchedulerKind::Heap);
    let mut now = 0.0f64;
    let mut id = 0usize;
    for round in 0..400usize {
        const K: usize = 64;
        for _ in 0..K {
            // Quantized offsets make simultaneous arrivals common — the
            // FIFO tie-break is the hard part of the parity contract.
            let dt = (rng.below(1000) as f64) * 12.5;
            wheel.push(now + dt, id);
            heap.push(now + dt, id);
            id += 1;
        }
        assert_eq!(wheel.len(), heap.len());
        assert_eq!(wheel.peek_time(), heap.peek_time());
        for _ in 0..rng.below(K + 1) {
            let a = wheel.pop();
            assert_eq!(a, heap.pop(), "divergence before event {id}");
            if let Some((t, _)) = a {
                now = t;
            }
        }
        // Semi-sync round cancellation: both schedulers drop the
        // pending set but keep sequencing.
        if round % 97 == 96 {
            wheel.clear();
            heap.clear();
        }
    }
    loop {
        let a = wheel.pop();
        assert_eq!(a, heap.pop());
        if a.is_none() {
            break;
        }
    }
    assert!(wheel.wheel_ops() > 0, "wheel must report bucket work");
}

/// Engine-level parity: for cohort processes *and* the pre-population
/// scenario processes, every discipline produces bit-identical
/// wall/rounds/upload_s under `SchedulerKind::Wheel` and
/// `SchedulerKind::Heap` — the scheduler swap is unobservable in results.
#[test]
fn engine_results_are_bit_identical_across_schedulers() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let run = |d: Discipline, kind: SchedulerKind, proc_: &mut dyn nacfl::netsim::NetworkProcess| {
        let mut policy = parse_policy("fixed:2").unwrap();
        let des = DesConfig::new(d, K_EPS).with_scheduler(kind);
        simulate_des(&ctx, policy.as_mut(), proc_, &des, Rng::new(1)).unwrap()
    };
    for seed in [0u64, 7] {
        // Sampled-cohort process (48 slots over a 50k population).
        for d in [
            Discipline::Sync,
            Discipline::SemiSync { k: 32 },
            Discipline::Async { staleness_exp: 0.5 },
        ] {
            let spec = PopSpec::parse("pop:50000:k48:classeshilo").unwrap();
            let scen = ScenarioKind::HeterogeneousIndependent;
            let mut pw = CohortProcess::new(spec.clone(), scen, seed).unwrap();
            let mut ph = CohortProcess::new(spec, scen, seed).unwrap();
            let rw = run(d, SchedulerKind::Wheel, &mut pw);
            let rh = run(d, SchedulerKind::Heap, &mut ph);
            assert_eq!(
                rw.wall.to_bits(),
                rh.wall.to_bits(),
                "pop {} seed {seed}: wall {} vs {}",
                d.label(),
                rw.wall,
                rh.wall
            );
            assert_eq!(rw.rounds, rh.rounds, "pop {} seed {seed}", d.label());
            assert_eq!(rw.aggregations, rh.aggregations);
            assert_eq!(rw.upload_s.to_bits(), rh.upload_s.to_bits());
            assert_eq!(rw.wait_s.to_bits(), rh.wait_s.to_bits());
        }
        // Pre-population scenario process (the legacy 10-client fleet).
        for d in [
            Discipline::Sync,
            Discipline::SemiSync { k: 7 },
            Discipline::Async { staleness_exp: 0.5 },
        ] {
            let scenario = Scenario::new(ScenarioKind::HeterogeneousIndependent, cfg.m);
            let mut pw = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
            let mut ph = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
            let rw = run(d, SchedulerKind::Wheel, &mut pw);
            let rh = run(d, SchedulerKind::Heap, &mut ph);
            assert_eq!(rw.wall.to_bits(), rh.wall.to_bits(), "base {} seed {seed}", d.label());
            assert_eq!(rw.rounds, rh.rounds);
            assert_eq!(rw.upload_s.to_bits(), rh.upload_s.to_bits());
        }
    }
}

#[test]
fn pop_free_campaigns_keep_the_pre_population_byte_shape() {
    let plain = ExperimentPlan::builder("pop parity")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .build()
        .unwrap();
    let explicit = ExperimentPlan::builder("pop parity")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .pop(["none"])
        .build()
        .unwrap();
    assert_eq!(
        plain.plan_hash(),
        explicit.plan_hash(),
        "a trivial pop axis must not re-key the campaign"
    );

    let la = temp("none_a");
    let lb = temp("none_b");
    for p in [&la, &lb] {
        let _ = std::fs::remove_file(p);
    }
    execute(&plain, &opts_for(&la, 1), &mut []).unwrap();
    execute(&explicit, &opts_for(&lb, 1), &mut []).unwrap();
    let bytes_a = std::fs::read_to_string(&la).unwrap();
    let bytes_b = std::fs::read_to_string(&lb).unwrap();
    assert_eq!(bytes_a, bytes_b);
    // Pop-free ledgers carry no population fields on any line, and keys
    // keep the pre-pop shape.
    assert!(!bytes_a.contains("\"pop\""));
    assert!(!bytes_a.contains("sampled_k"));
    assert!(!bytes_a.contains("participation"));

    for p in [&la, &lb] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn pop_campaigns_are_deterministic_across_runs_threads_and_shards() {
    const POP: &str = "pop:20000:k16:classeshilo";
    let plan = ExperimentPlan::builder("pop determinism")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .pop(["none", POP])
        .build()
        .unwrap();
    let n = plan.n_runs();
    assert_eq!(n, 8);

    let la = temp("det_a");
    let lb = temp("det_b");
    for p in [&la, &lb] {
        let _ = std::fs::remove_file(p);
    }
    // Single-threaded double run: byte-identical ledgers (records *and*
    // layout), exactly the fault-axis contract.
    let full = execute(&plan, &opts_for(&la, 1), &mut []).unwrap();
    execute(&plan, &opts_for(&lb, 1), &mut []).unwrap();
    let bytes_a = std::fs::read_to_string(&la).unwrap();
    let bytes_b = std::fs::read_to_string(&lb).unwrap();
    assert_eq!(bytes_a, bytes_b, "double run must be byte-identical");

    // Record shape: pop cells carry the coordinate, its cohort size and
    // a participation summary; the pop:none twins stay backfill-clean.
    assert_eq!(full.records.len(), n);
    for r in &full.records {
        if r.pop == "none" {
            assert!(r.sampled_k.is_nan(), "{}", r.key());
            assert!(r.participation.is_empty());
        } else {
            assert_eq!(r.pop, POP);
            assert_eq!(r.sampled_k, 16.0, "{}", r.key());
            assert!(r.key().ends_with(&format!("|{POP}")), "{}", r.key());
            assert!(!r.participation.is_empty(), "{}", r.key());
            assert!(r.wall > 0.0 && r.rounds > 0);
        }
    }

    // Thread-count invariance: same record bits in plan order.
    let lc = temp("det_c");
    let _ = std::fs::remove_file(&lc);
    let par = execute(&plan, &opts_for(&lc, 4), &mut []).unwrap();
    for (a, b) in full.records.iter().zip(par.records.iter()) {
        assert_eq!(a.key(), b.key());
        assert_eq!(a.wall.to_bits(), b.wall.to_bits(), "4 threads: {}", a.key());
        assert_eq!(a.participation, b.participation, "{}", a.key());
    }

    // Shard split: the Pop cost class splits its 4 cells 2/2 (cohort-
    // size weighted), and the fleet merges bit-identically to solo.
    let ls0 = temp("det_s0");
    let ls1 = temp("det_s1");
    for p in [&ls0, &ls1] {
        let _ = std::fs::remove_file(p);
    }
    let mk = |ledger: &str, spec: &str| ExecOptions {
        shard: ShardSpec::parse(spec).unwrap(),
        ..opts_for(ledger, 2)
    };
    let s0 = execute(&plan, &mk(&ls0, "0/2"), &mut []).unwrap();
    let s1 = execute(&plan, &mk(&ls1, "1/2"), &mut []).unwrap();
    assert_eq!(s0.records.len() + s1.records.len(), n, "disjoint and exhaustive");
    for shard in [&s0, &s1] {
        let pop = shard.records.iter().filter(|r| r.pop != "none").count();
        assert_eq!(pop, 2, "pop cells split evenly across shards");
    }
    let merged = merge_ledgers(&[&ls0, &ls1], Some(&plan)).unwrap();
    assert!(merged.complete(), "missing: {:?}", merged.missing);
    for (x, y) in full.records.iter().zip(merged.records.iter()) {
        assert_eq!(x.key(), y.key(), "merge must return plan order");
        assert_eq!(x.wall.to_bits(), y.wall.to_bits(), "{}", x.key());
        assert_eq!(x.participation, y.participation, "{}", x.key());
    }

    // With telemetry on, sampling volume, per-class participation and
    // wheel work all surface as counters.
    let lt = temp("det_telem");
    let _ = std::fs::remove_file(&lt);
    let opts = ExecOptions {
        telemetry: true,
        ..opts_for(&lt, 2)
    };
    execute(&plan, &opts, &mut []).unwrap();
    let telem = std::fs::read_to_string(&lt).unwrap();
    assert!(telem.contains("pop.sampled"), "sampling volume must be counted");
    assert!(telem.contains("pop.class0"), "per-class participation must be counted");
    assert!(telem.contains("des.wheel_ops"), "wheel work must be counted");

    for p in [&la, &lb, &lc, &ls0, &ls1, &lt] {
        std::fs::remove_file(p).ok();
    }
}

/// The ledger's participation summary reproduces the class mixture: on
/// `classeshilo` (0.8 / 0.2), class 0's share of all sampled slots
/// lands near 0.8.
#[test]
fn ledger_participation_matches_mixture_weights() {
    let mut base = small_base();
    base.seeds = vec![0];
    base.policies = vec!["fixed:2".into()];
    let plan = ExperimentPlan::builder("pop mixture")
        .base(base)
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .pop(["pop:100000:k200:classeshilo"])
        .build()
        .unwrap();
    let ledger = temp("mixture");
    let _ = std::fs::remove_file(&ledger);
    let out = execute(&plan, &opts_for(&ledger, 1), &mut []).unwrap();
    assert_eq!(out.records.len(), 1);
    let r = &out.records[0];
    let mut counts = [0u64; 2];
    for part in r.participation.split(',') {
        let (c, n) = part.split_once(':').expect("class:count");
        counts[c.parse::<usize>().unwrap()] += n.parse::<u64>().unwrap();
    }
    let total = counts.iter().sum::<u64>();
    assert!(total > 0 && total % 200 == 0, "K slots per sampled round, got {total}");
    assert!(total >= 200 * r.rounds as u64, "at least one cohort per round");
    let frac0 = counts[0] as f64 / total as f64;
    assert!(
        (frac0 - 0.8).abs() < 0.05,
        "class-0 participation {frac0:.3} vs mixture weight 0.8 ({total} draws)"
    );
    std::fs::remove_file(&ledger).ok();
}

/// Fault channels compose with sampled cohorts: the per-cohort fault
/// stream is coordinate-pure, and the record carries both gated blocks.
#[test]
fn pop_composes_with_the_fault_axis() {
    let mut base = small_base();
    base.seeds = vec![0];
    base.policies = vec!["fixed:2".into()];
    let plan = ExperimentPlan::builder("pop faults")
        .base(base)
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .faults(["loss:0.3:retry2"])
        .pop(["pop:5000:k8"])
        .build()
        .unwrap();
    let la = temp("faults_a");
    let lb = temp("faults_b");
    for p in [&la, &lb] {
        let _ = std::fs::remove_file(p);
    }
    let a = execute(&plan, &opts_for(&la, 1), &mut []).unwrap();
    execute(&plan, &opts_for(&lb, 1), &mut []).unwrap();
    assert_eq!(
        std::fs::read_to_string(&la).unwrap(),
        std::fs::read_to_string(&lb).unwrap(),
        "faulty pop cell must double-run byte-identically"
    );
    let r = &a.records[0];
    assert_eq!(r.faults, "loss:0.3:retry2");
    assert_eq!(r.pop, "pop:5000:k8");
    assert!(r.key().ends_with("|loss:0.3:retry2|pop:5000:k8"), "{}", r.key());
    assert!(r.retrans_s.is_finite() && r.retrans_s >= 0.0);
    assert!(!r.participation.is_empty());
    for p in [&la, &lb] {
        std::fs::remove_file(p).ok();
    }
}

/// A million-client cell runs in cohort time: state stays O(K), the
/// sampled roster spreads across the whole population, and the engine
/// converges like any other DES run.
#[test]
fn million_client_cell_stays_cohort_sized() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let spec = PopSpec::parse("pop:1000000:k1000").unwrap();
    let mut proc_ =
        CohortProcess::new(spec, ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 }, 3)
            .unwrap();
    let mut policy = parse_policy("fixed:2").unwrap();
    let des = DesConfig::new(Discipline::Sync, K_EPS);
    let r = simulate_des(&ctx, policy.as_mut(), &mut proc_, &des, Rng::new(1)).unwrap();
    assert!(r.converged, "million-client cell must converge");
    assert!(r.rounds > 0 && r.wall > 0.0);
    // Cohort state never grows past K, regardless of N.
    assert_eq!(proc_.indices.len(), 1000);
    assert_eq!(proc_.slot_class.len(), 1000);
    assert_eq!(proc_.sampled_total(), 1000 * proc_.rounds);
    // Distinct rounds draw from far-apart corners of the population.
    let span = proc_.indices.last().unwrap() - proc_.indices.first().unwrap();
    assert!(span > 500_000, "cohort should span the population, got {span}");
}

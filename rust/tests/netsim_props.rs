//! Integration properties for the netsim observation layer:
//! the §V probe estimator converges toward the true BTD as probes
//! accumulate, and trace save→load round-trips preserve the trace.

use nacfl::netsim::estimator::ProbeEstimator;
use nacfl::netsim::trace_io::{load_trace, parse_trace, save_trace};
use nacfl::netsim::{NetworkProcess, Scenario, ScenarioKind};
use nacfl::util::rng::Rng;

#[test]
fn probe_estimator_converges_toward_true_btd_with_probe_count() {
    // Mean absolute relative error across independent estimator streams
    // must shrink as probes accumulate, and end close to the truth.
    let c_true = vec![3.0, 0.5, 12.0];
    // With alpha = 0.02 the EWMA's memory of the first noisy probe decays
    // over ~200 probes, so the three checkpoints sit in cleanly separated
    // error regimes (~0.23, ~0.17, ~0.03 mean abs relative error).
    let checkpoints = [2usize, 20, 200];
    let n_streams = 20u64;
    let mut errs = vec![0.0f64; checkpoints.len()];
    for s in 0..n_streams {
        let mut est = ProbeEstimator::new(c_true.len(), 0.02, 0.3, Rng::new(1000 + s));
        let mut probes = 0usize;
        for (ci, &upto) in checkpoints.iter().enumerate() {
            let mut last = Vec::new();
            while probes < upto {
                last = est.observe(&c_true);
                probes += 1;
            }
            let err: f64 = last
                .iter()
                .zip(c_true.iter())
                .map(|(e, t)| ((e - t) / t).abs())
                .sum::<f64>()
                / c_true.len() as f64;
            errs[ci] += err / n_streams as f64;
        }
    }
    assert!(
        errs[0] > errs[1] && errs[1] > errs[2],
        "error must shrink with probe count: {errs:?}"
    );
    assert!(errs[2] < 0.06, "converged error too large: {errs:?}");
}

#[test]
fn probe_estimator_is_unbiased_in_the_long_run() {
    let c_true = vec![4.0];
    let mut est = ProbeEstimator::new(1, 0.2, 0.25, Rng::new(9));
    // Burn in, then average the estimate over many probes.
    for _ in 0..500 {
        est.observe(&c_true);
    }
    let n = 20_000;
    let mut acc = 0.0;
    for _ in 0..n {
        acc += est.observe(&c_true)[0];
    }
    let mean = acc / n as f64;
    assert!((mean - 4.0).abs() / 4.0 < 0.03, "long-run mean {mean}");
}

#[test]
fn trace_write_read_round_trip_preserves_the_trace() {
    // A trace sampled from a real scenario, saved and reloaded, replays
    // the same BTD path (to the 1e-9 precision of the CSV format).
    let m = 7;
    let rounds = 50;
    let scenario = Scenario::new(ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 }, m);
    let mut process = scenario.process(Rng::new(11).derive("net", 0)).unwrap();
    let rows: Vec<Vec<f64>> = (0..rounds).map(|_| process.next_state()).collect();

    let path = std::env::temp_dir().join(format!("nacfl_roundtrip_{}.csv", std::process::id()));
    save_trace(&path, &rows).unwrap();
    let mut replay = load_trace(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(replay.dim(), m);
    for (n, row) in rows.iter().enumerate() {
        let got = replay.next_state();
        assert_eq!(got.len(), m);
        for (j, (&g, &want)) in got.iter().zip(row.iter()).enumerate() {
            let rel = (g - want).abs() / want.abs();
            assert!(rel < 1e-8, "round {n} client {j}: {g} vs {want} (rel {rel:.2e})");
        }
    }
    // And the replay is cyclic: round `rounds` equals round 0.
    let wrapped = replay.next_state();
    let rel = (wrapped[0] - rows[0][0]).abs() / rows[0][0].abs();
    assert!(rel < 1e-8);
}

#[test]
fn parse_trace_rejects_malformed_input_cleanly() {
    assert!(parse_trace("1.0,2.0\n3.0\n").is_err(), "ragged rows");
    assert!(parse_trace("1.0,-2.0\n").is_err(), "non-positive BTD");
    assert!(parse_trace("1.0,nan\n").is_err(), "NaN BTD");
    assert!(parse_trace("# only comments\n").is_err(), "no data rows");
    // Header + comments are tolerated.
    let t = parse_trace("# hdr\nc1,c2\n0.25,0.75\n").unwrap();
    assert_eq!(t, vec![vec![0.25, 0.75]]);
}

//! System tests for the telemetry subsystem (ISSUE-6):
//!
//! * telemetry **off vs on** leaves every run record, every ledger
//!   record line, and every paper table byte-identical — observation
//!   must not perturb the engines' frozen float paths;
//! * every record's delay decomposition sums back to its wall clock
//!   within 1e-9 across the closed form and all three DES disciplines;
//! * `"kind":"telem"` lines survive a full trip through the distributed
//!   ledger reader and re-serialize byte-for-byte;
//! * the resume machinery never mistakes a telem line for a run.

use nacfl::config::ExperimentConfig;
use nacfl::exp::{build_tables, execute, read_dist_ledger, ExecOptions, ExperimentPlan, Tier};
use nacfl::obs::TelemLine;

fn temp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nacfl_obs_sys_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// 18 analytic runs (2 policies x 3 seeds x 3 disciplines): the sync
/// closed form plus the DES engine under every aggregation discipline,
/// so the decomposition invariant is exercised on each wall-clock path.
fn test_plan() -> ExperimentPlan {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..3).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    ExperimentPlan::builder("obs demo")
        .base(base)
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .disciplines(vec![
            nacfl::des::Discipline::Sync,
            nacfl::des::Discipline::SemiSync { k: 7 },
            nacfl::des::Discipline::Async { staleness_exp: 1.0 },
        ])
        .build()
        .unwrap()
}

fn opts(ledger: &str, telemetry: bool) -> ExecOptions {
    ExecOptions {
        // Single-threaded => deterministic completion (and ledger line)
        // order, so the off/on ledgers are comparable line by line.
        threads: 1,
        ledger: Some(ledger.to_string()),
        telemetry,
        ..Default::default()
    }
}

/// The record lines of a ledger: everything that is not kind-tagged
/// (plan header / claim / telem lines all carry `"kind"`).
fn record_lines(text: &str) -> Vec<&str> {
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.contains("\"kind\":"))
        .collect()
}

#[test]
fn telemetry_off_and_on_produce_bit_identical_records_and_tables() {
    let plan = test_plan();
    let n = plan.n_runs();

    let l_off = temp("off");
    let l_on = temp("on");
    let _ = std::fs::remove_file(&l_off);
    let _ = std::fs::remove_file(&l_on);

    let off = execute(&plan, &opts(&l_off, false), &mut []).unwrap();
    let on = execute(&plan, &opts(&l_on, true), &mut []).unwrap();
    assert_eq!(off.records.len(), n);
    assert_eq!(on.records.len(), n);

    // Ledger record lines: byte-identical, in identical order.
    let t_off = std::fs::read_to_string(&l_off).unwrap();
    let t_on = std::fs::read_to_string(&l_on).unwrap();
    let r_off = record_lines(&t_off);
    let r_on = record_lines(&t_on);
    assert_eq!(r_off.len(), n);
    assert_eq!(
        r_off, r_on,
        "telemetry must not perturb a single record byte"
    );

    // Only the telemetry run streams telem lines.
    assert!(!t_off.contains("\"kind\":\"telem\""), "off => no telem lines");
    assert!(t_on.contains("\"kind\":\"telem\""), "on => telem lines stream");

    // Paper tables regenerate byte-identically from either summary.
    let tab = |records| -> Vec<String> {
        build_tables(None, records)
            .unwrap()
            .iter()
            .map(|t| t.render())
            .collect()
    };
    assert_eq!(tab(&off.records), tab(&on.records));

    std::fs::remove_file(&l_off).ok();
    std::fs::remove_file(&l_on).ok();
}

#[test]
fn delay_decomposition_sums_to_wall_on_every_path() {
    let plan = test_plan();
    let in_memory = ExecOptions { threads: 2, ..Default::default() };
    let summary = execute(&plan, &in_memory, &mut []).unwrap();
    assert_eq!(summary.records.len(), plan.n_runs());
    for r in &summary.records {
        let sum = r.upload_s + r.compute_s + r.wait_s;
        assert!(
            (sum - r.wall).abs() <= 1e-9 * r.wall.abs().max(1.0),
            "{}: upload {} + compute {} + wait {} = {} != wall {}",
            r.key(),
            r.upload_s,
            r.compute_s,
            r.wait_s,
            sum,
            r.wall
        );
        assert!(r.upload_s.is_finite() && r.compute_s.is_finite() && r.wait_s.is_finite());
        // Transmission time is physical on every analytic/DES path.
        assert!(r.upload_s >= 0.0, "{}: negative upload_s {}", r.key(), r.upload_s);
    }
    // Early-close disciplines must exist in the mix (they are the
    // reason wait_s is allowed to go negative).
    assert!(summary.records.iter().any(|r| r.discipline != "sync"));
}

#[test]
fn telem_lines_round_trip_through_the_dist_ledger_reader() {
    let plan = test_plan();
    let n = plan.n_runs();
    let ls = temp("trip");
    let _ = std::fs::remove_file(&ls);
    let summary = execute(&plan, &opts(&ls, true), &mut []).unwrap();
    assert_eq!(summary.records.len(), n);

    let led = read_dist_ledger(&ls).unwrap();
    assert_eq!(led.runs.len(), n);
    assert_eq!(led.n_torn, 0, "telem lines must parse cleanly");
    assert!(!led.telem.is_empty(), "telemetry run must stream telem lines");

    // Per-run scope keyed by run coordinates; campaign scope keyed by
    // worker id ("local" when none was set).
    let keys: std::collections::BTreeSet<_> =
        led.runs.iter().map(|r| r.key()).collect();
    assert!(led
        .telem
        .iter()
        .filter(|t| t.scope == "run")
        .all(|t| keys.contains(&t.key)));
    assert!(led
        .telem
        .iter()
        .any(|t| t.scope == "campaign" && t.key == "local"));

    // The metric namespace covers all instrumented layers: session
    // round loop, DES engine, solver, and the execution engine.
    for metric in [
        "sim.rounds",
        "des.rounds",
        "des.events_popped",
        "solver.solves",
        "exp.runs_started",
        "exp.runs_completed",
    ] {
        assert!(
            led.telem.iter().any(|t| t.metric == metric),
            "missing metric {metric} in {:?}",
            led.telem.iter().map(|t| &t.metric).collect::<Vec<_>>()
        );
    }

    // Byte-stable round trip for every line the engine wrote.
    let text = std::fs::read_to_string(&ls).unwrap();
    let wire: Vec<&str> = text
        .lines()
        .filter(|l| l.contains("\"kind\":\"telem\""))
        .collect();
    assert_eq!(wire.len(), led.telem.len());
    for (line, parsed) in wire.iter().zip(led.telem.iter()) {
        assert_eq!(&parsed.to_json(), line, "re-serialization must be byte-stable");
        assert_eq!(&TelemLine::from_json(line).unwrap(), parsed);
    }

    // Resume sees only the records: a second pass re-executes nothing
    // and appends no duplicate records.
    let resumed = execute(&plan, &opts(&ls, false), &mut []).unwrap();
    assert_eq!(resumed.n_cached, n, "telem lines are invisible to resume");
    assert_eq!(resumed.n_executed, 0);
    let led2 = read_dist_ledger(&ls).unwrap();
    assert_eq!(led2.runs.len(), n, "no duplicate records on resume");

    std::fs::remove_file(&ls).ok();
}

//! System tests for the telemetry subsystem (ISSUE-6):
//!
//! * telemetry **off vs on** leaves every run record, every ledger
//!   record line, and every paper table byte-identical — observation
//!   must not perturb the engines' frozen float paths;
//! * every record's delay decomposition sums back to its wall clock
//!   within 1e-9 across the closed form and all three DES disciplines;
//! * `"kind":"telem"` lines survive a full trip through the distributed
//!   ledger reader and re-serialize byte-for-byte;
//! * the resume machinery never mistakes a telem line for a run.
//!
//! PR-10 adds the round-series recorder and event-trace pins:
//!
//! * series/trace **off** leaves the ledger byte-identical run to run
//!   (and free of `"kind":"series"` lines); series **on** adds exactly
//!   one bounded series line per run without perturbing a record byte;
//! * series bytes are identical across thread counts (pure function of
//!   run coordinates);
//! * a million-client population cell running hundreds of rounds still
//!   fits one bounded ledger line, via deterministic stride-doubling
//!   decimation;
//! * `--trace` writes a valid Chrome `trace_event` JSON array with
//!   client-upload duration events and a link-utilization counter
//!   track for a `flow:` cell.

use nacfl::config::ExperimentConfig;
use nacfl::des::{simulate_des_obs, DesConfig, Discipline};
use nacfl::exp::{build_tables, execute, read_dist_ledger, ExecOptions, ExperimentPlan, Tier};
use nacfl::netsim::ScenarioKind;
use nacfl::obs::{RoundSeries, SeriesLine, TelemLine, Telemetry, TraceRecorder, SERIES_CAP};
use nacfl::policy::{PolicyEnv, PolicySpec};
use nacfl::pop::{CohortProcess, PopSpec};
use nacfl::util::rng::Rng;

fn temp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nacfl_obs_sys_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// 18 analytic runs (2 policies x 3 seeds x 3 disciplines): the sync
/// closed form plus the DES engine under every aggregation discipline,
/// so the decomposition invariant is exercised on each wall-clock path.
fn test_plan() -> ExperimentPlan {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..3).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    ExperimentPlan::builder("obs demo")
        .base(base)
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .disciplines(vec![
            nacfl::des::Discipline::Sync,
            nacfl::des::Discipline::SemiSync { k: 7 },
            nacfl::des::Discipline::Async { staleness_exp: 1.0 },
        ])
        .build()
        .unwrap()
}

fn opts(ledger: &str, telemetry: bool) -> ExecOptions {
    ExecOptions {
        // Single-threaded => deterministic completion (and ledger line)
        // order, so the off/on ledgers are comparable line by line.
        threads: 1,
        ledger: Some(ledger.to_string()),
        telemetry,
        ..Default::default()
    }
}

/// The record lines of a ledger: everything that is not kind-tagged
/// (plan header / claim / telem lines all carry `"kind"`).
fn record_lines(text: &str) -> Vec<&str> {
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.contains("\"kind\":"))
        .collect()
}

#[test]
fn telemetry_off_and_on_produce_bit_identical_records_and_tables() {
    let plan = test_plan();
    let n = plan.n_runs();

    let l_off = temp("off");
    let l_on = temp("on");
    let _ = std::fs::remove_file(&l_off);
    let _ = std::fs::remove_file(&l_on);

    let off = execute(&plan, &opts(&l_off, false), &mut []).unwrap();
    let on = execute(&plan, &opts(&l_on, true), &mut []).unwrap();
    assert_eq!(off.records.len(), n);
    assert_eq!(on.records.len(), n);

    // Ledger record lines: byte-identical, in identical order.
    let t_off = std::fs::read_to_string(&l_off).unwrap();
    let t_on = std::fs::read_to_string(&l_on).unwrap();
    let r_off = record_lines(&t_off);
    let r_on = record_lines(&t_on);
    assert_eq!(r_off.len(), n);
    assert_eq!(
        r_off, r_on,
        "telemetry must not perturb a single record byte"
    );

    // Only the telemetry run streams telem lines.
    assert!(!t_off.contains("\"kind\":\"telem\""), "off => no telem lines");
    assert!(t_on.contains("\"kind\":\"telem\""), "on => telem lines stream");

    // Paper tables regenerate byte-identically from either summary.
    let tab = |records| -> Vec<String> {
        build_tables(None, records)
            .unwrap()
            .iter()
            .map(|t| t.render())
            .collect()
    };
    assert_eq!(tab(&off.records), tab(&on.records));

    std::fs::remove_file(&l_off).ok();
    std::fs::remove_file(&l_on).ok();
}

#[test]
fn delay_decomposition_sums_to_wall_on_every_path() {
    let plan = test_plan();
    let in_memory = ExecOptions { threads: 2, ..Default::default() };
    let summary = execute(&plan, &in_memory, &mut []).unwrap();
    assert_eq!(summary.records.len(), plan.n_runs());
    for r in &summary.records {
        let sum = r.upload_s + r.compute_s + r.wait_s;
        assert!(
            (sum - r.wall).abs() <= 1e-9 * r.wall.abs().max(1.0),
            "{}: upload {} + compute {} + wait {} = {} != wall {}",
            r.key(),
            r.upload_s,
            r.compute_s,
            r.wait_s,
            sum,
            r.wall
        );
        assert!(r.upload_s.is_finite() && r.compute_s.is_finite() && r.wait_s.is_finite());
        // Transmission time is physical on every analytic/DES path.
        assert!(r.upload_s >= 0.0, "{}: negative upload_s {}", r.key(), r.upload_s);
    }
    // Early-close disciplines must exist in the mix (they are the
    // reason wait_s is allowed to go negative).
    assert!(summary.records.iter().any(|r| r.discipline != "sync"));
}

#[test]
fn telem_lines_round_trip_through_the_dist_ledger_reader() {
    let plan = test_plan();
    let n = plan.n_runs();
    let ls = temp("trip");
    let _ = std::fs::remove_file(&ls);
    let summary = execute(&plan, &opts(&ls, true), &mut []).unwrap();
    assert_eq!(summary.records.len(), n);

    let led = read_dist_ledger(&ls).unwrap();
    assert_eq!(led.runs.len(), n);
    assert_eq!(led.n_torn, 0, "telem lines must parse cleanly");
    assert!(!led.telem.is_empty(), "telemetry run must stream telem lines");

    // Per-run scope keyed by run coordinates; campaign scope keyed by
    // worker id ("local" when none was set).
    let keys: std::collections::BTreeSet<_> =
        led.runs.iter().map(|r| r.key()).collect();
    assert!(led
        .telem
        .iter()
        .filter(|t| t.scope == "run")
        .all(|t| keys.contains(&t.key)));
    assert!(led
        .telem
        .iter()
        .any(|t| t.scope == "campaign" && t.key == "local"));

    // The metric namespace covers all instrumented layers: session
    // round loop, DES engine, solver, and the execution engine.
    for metric in [
        "sim.rounds",
        "des.rounds",
        "des.events_popped",
        "solver.solves",
        "exp.runs_started",
        "exp.runs_completed",
    ] {
        assert!(
            led.telem.iter().any(|t| t.metric == metric),
            "missing metric {metric} in {:?}",
            led.telem.iter().map(|t| &t.metric).collect::<Vec<_>>()
        );
    }

    // Byte-stable round trip for every line the engine wrote.
    let text = std::fs::read_to_string(&ls).unwrap();
    let wire: Vec<&str> = text
        .lines()
        .filter(|l| l.contains("\"kind\":\"telem\""))
        .collect();
    assert_eq!(wire.len(), led.telem.len());
    for (line, parsed) in wire.iter().zip(led.telem.iter()) {
        assert_eq!(&parsed.to_json(), line, "re-serialization must be byte-stable");
        assert_eq!(&TelemLine::from_json(line).unwrap(), parsed);
    }

    // Resume sees only the records: a second pass re-executes nothing
    // and appends no duplicate records.
    let resumed = execute(&plan, &opts(&ls, false), &mut []).unwrap();
    assert_eq!(resumed.n_cached, n, "telem lines are invisible to resume");
    assert_eq!(resumed.n_executed, 0);
    let led2 = read_dist_ledger(&ls).unwrap();
    assert_eq!(led2.runs.len(), n, "no duplicate records on resume");

    std::fs::remove_file(&ls).ok();
}

#[test]
fn series_off_ledgers_are_byte_identical_and_on_adds_only_series_lines() {
    let plan = test_plan();
    let l_a = temp("soff_a");
    let l_b = temp("soff_b");
    let l_on = temp("son");
    for l in [&l_a, &l_b, &l_on] {
        let _ = std::fs::remove_file(l);
    }

    // Series off: two fresh runs produce the same ledger byte for byte
    // (single-threaded, no worker id => no wall-clock claim stamps).
    execute(&plan, &opts(&l_a, false), &mut []).unwrap();
    execute(&plan, &opts(&l_b, false), &mut []).unwrap();
    let t_a = std::fs::read_to_string(&l_a).unwrap();
    let t_b = std::fs::read_to_string(&l_b).unwrap();
    assert_eq!(t_a, t_b, "series-off ledgers must be byte-identical run to run");
    assert!(!t_a.contains("\"kind\":\"series\""), "off => no series lines");

    // Series on: the record stream is untouched; the only new bytes are
    // `"kind":"series"` lines, one per run, each parse/print stable.
    let on = ExecOptions { series: true, ..opts(&l_on, false) };
    execute(&plan, &on, &mut []).unwrap();
    let t_on = std::fs::read_to_string(&l_on).unwrap();
    assert_eq!(
        record_lines(&t_a),
        record_lines(&t_on),
        "series recording must not perturb a record byte"
    );
    assert!(t_on.contains("\"kind\":\"series\""), "on => series lines stream");
    let led = read_dist_ledger(&l_on).unwrap();
    assert_eq!(led.series.len(), plan.n_runs(), "one series line per run");
    for line in t_on.lines().filter(|l| l.contains("\"kind\":\"series\"")) {
        assert_eq!(
            SeriesLine::from_json(line).unwrap().to_json(),
            line,
            "series re-serialization must be byte-stable"
        );
    }

    // Resume sees only the records, exactly as with telem lines.
    let resumed = execute(&plan, &opts(&l_on, false), &mut []).unwrap();
    assert_eq!(resumed.n_cached, plan.n_runs(), "series lines are invisible to resume");
    assert_eq!(resumed.n_executed, 0);

    for l in [&l_a, &l_b, &l_on] {
        std::fs::remove_file(l).ok();
    }
}

#[test]
fn series_lines_are_identical_across_thread_counts() {
    let plan = test_plan();
    let l1 = temp("thr1");
    let l2 = temp("thr2");
    for l in [&l1, &l2] {
        let _ = std::fs::remove_file(l);
    }
    let o1 = ExecOptions { series: true, ..opts(&l1, false) };
    let o2 = ExecOptions {
        threads: 2,
        series: true,
        ledger: Some(l2.clone()),
        ..Default::default()
    };
    execute(&plan, &o1, &mut []).unwrap();
    execute(&plan, &o2, &mut []).unwrap();
    let series_lines = |p: &str| -> Vec<String> {
        let mut v: Vec<String> = std::fs::read_to_string(p)
            .unwrap()
            .lines()
            .filter(|l| l.contains("\"kind\":\"series\""))
            .map(str::to_string)
            .collect();
        v.sort();
        v
    };
    let a = series_lines(&l1);
    let b = series_lines(&l2);
    assert_eq!(a.len(), plan.n_runs());
    assert_eq!(a, b, "series bytes are a pure function of run coordinates");
    for l in [&l1, &l2] {
        std::fs::remove_file(l).ok();
    }
}

#[test]
fn million_client_series_line_stays_bounded_and_decimates_deterministically() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let scen = ScenarioKind::parse("homog:2").unwrap();
    let run = || {
        let spec = PopSpec::parse("pop:1000000:k1000").unwrap();
        let k = spec.k;
        let mut process = CohortProcess::new(spec, scen, 3).unwrap();
        let env = PolicyEnv::for_cell(&ctx, scen, k, 3);
        let mut policy = PolicySpec::parse("fixed:2").unwrap().build(&env).unwrap();
        // A convergence target far out of reach: the run burns through
        // the whole round cap, pushing the recorder well past SERIES_CAP
        // so stride-doubling decimation has to kick in.
        let des = DesConfig::new(Discipline::Sync, 1e12).with_max_rounds(4 * SERIES_CAP);
        let mut series = RoundSeries::on();
        simulate_des_obs(
            &ctx,
            policy.as_mut(),
            &mut process,
            &des,
            Rng::new(3).derive("des-fault", 0),
            &mut Telemetry::off(),
            &mut series,
            &mut TraceRecorder::off(),
        )
        .unwrap();
        series
    };
    let a = run();
    assert_eq!(a.rounds_total(), (4 * SERIES_CAP) as u64);
    assert!(a.len() <= SERIES_CAP, "kept rounds stay under the cap");
    assert!(a.stride() >= 4, "stride doubles as rounds accumulate");
    let line = a.line("pop-cell").unwrap().to_json();
    assert!(
        line.len() < 64 * 1024,
        "a million-client long run still fits one bounded ledger line ({} bytes)",
        line.len()
    );
    let b = run();
    assert_eq!(
        b.line("pop-cell").unwrap().to_json(),
        line,
        "decimation is a pure function of the sample path"
    );
}

#[test]
fn trace_export_writes_valid_chrome_trace_events_for_a_flow_cell() {
    let mut base = ExperimentConfig::paper();
    base.seeds = vec![0];
    base.policies = vec!["fixed:2".into()];
    let plan = ExperimentPlan::builder("trace demo")
        .base(base)
        .scenarios([ScenarioKind::parse("flow:tower:2x5").unwrap()])
        .tiers([Tier::Analytic { k_eps: 40.0 }])
        .build()
        .unwrap();
    let ledger = temp("trace_led");
    let trace = std::env::temp_dir()
        .join(format!("nacfl_obs_sys_trace_{}.json", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();
    let _ = std::fs::remove_file(&ledger);
    let _ = std::fs::remove_file(&trace);

    let o = ExecOptions { trace: Some(trace.clone()), ..opts(&ledger, false) };
    execute(&plan, &o, &mut []).unwrap();

    let text = std::fs::read_to_string(&trace).unwrap();
    let t = text.trim();
    assert!(t.starts_with('[') && t.ends_with(']'), "a JSON array of events");
    assert_eq!(
        t.matches('{').count(),
        t.matches('}').count(),
        "every event object closes"
    );
    // Per-run process metadata names the run, so multi-run traces get
    // one labeled track group per run in the viewer.
    assert!(text.contains("\"name\":\"process_name\""), "run metadata row");
    assert!(text.contains("\"ph\":\"M\""));
    // Client uploads land as duration events on per-client tracks.
    assert!(text.contains("\"name\":\"upload\""), "upload spans: {text}");
    assert!(text.contains("\"ph\":\"X\""));
    assert!(text.contains("\"dur\":"));
    // The shared bottleneck contributes a link-utilization counter track.
    assert!(text.contains("\"name\":\"link0.util\""), "link counter: {text}");
    assert!(text.contains("\"ph\":\"C\""));
    assert!(text.contains("\"args\":{\"util\":"));
    // The trace is a sidecar: the ledger record stream is unchanged.
    let with_trace = std::fs::read_to_string(&ledger).unwrap();
    let _ = std::fs::remove_file(&ledger);
    execute(&plan, &opts(&ledger, false), &mut []).unwrap();
    let without = std::fs::read_to_string(&ledger).unwrap();
    assert_eq!(record_lines(&with_trace), record_lines(&without));

    std::fs::remove_file(&ledger).ok();
    std::fs::remove_file(&trace).ok();
}

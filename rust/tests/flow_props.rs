//! Property tests for the flow-level bandwidth-sharing network
//! (DESIGN.md §13): the weighted max-min allocation never oversubscribes
//! a link, the allocation is independent of admission order, and a
//! topology with no shared links reproduces the exogenous analytic
//! delay path bit-identically through the campaign engine.

use nacfl::config::ExperimentConfig;
use nacfl::exp::{execute, ExecOptions, ExperimentPlan, RunRecord, Tier};
use nacfl::netsim::{FlowNet, FlowPreset, ScenarioKind};
use nacfl::obs::Telemetry;
use nacfl::util::rng::Rng;
use std::collections::HashMap;

/// Every shared link's allocated client rate stays within capacity
/// (cross-traffic only ever shrinks the client share, never inflates it).
fn assert_caps(net: &FlowNet, tag: &str) {
    for (l, (load, cap)) in net.link_loads().into_iter().enumerate() {
        assert!(cap > 0.0 && cap.is_finite(), "{tag}: link {l} capacity {cap}");
        assert!(load.is_finite(), "{tag}: link {l} load {load}");
        assert!(
            load <= cap * (1.0 + 1e-9),
            "{tag}: link {l} oversubscribed: load {load} > cap {cap}"
        );
    }
}

#[test]
fn max_min_allocation_never_oversubscribes_any_link() {
    let m = 12usize;
    let presets = ["tower:2x3", "tower:4x8:x1.5", "ingress", "ingress:x2", "shared:0.5"];
    for spec in presets {
        let preset = FlowPreset::parse(spec).unwrap();
        let mut reprices = 0u64;
        for seed in 0..5u64 {
            let mut telem = Telemetry::off();
            let rng = Rng::new(seed).derive("flow", 0);
            let mut net = FlowNet::new(&preset, m, &rng, 1.0).unwrap();
            let mut draws = Rng::new(seed).derive("jobs", 0);
            net.begin_round(0.0, &mut telem);
            // The invariant must hold at every allocation change: after
            // each admission and after each completion/cross toggle.
            for j in 0..m {
                let bits = 1000.0 * (1.0 + draws.uniform());
                let solo_btd = 0.5 + 4.0 * draws.uniform();
                net.admit(j, bits, solo_btd, &mut telem);
                assert_caps(&net, spec);
            }
            while net.next_completion(&mut telem).is_some() {
                assert_caps(&net, spec);
            }
            assert!(
                net.congestion_s().is_finite() && net.congestion_s() >= 0.0,
                "{spec}: congestion accumulator stays a real nonnegative total"
            );
            reprices += net.rate_changes();
        }
        // All of these presets share a bottleneck, so across five seeded
        // rounds of twelve concurrent uploads somebody must be repriced.
        assert!(reprices > 0, "{spec}: shared preset never repriced a flow");
    }
}

#[test]
fn max_min_shares_are_independent_of_admission_order() {
    let m = 12usize;
    let preset = FlowPreset::parse("tower:3x4").unwrap();
    for seed in 0..8u64 {
        let mut draws = Rng::new(900 + seed);
        let jobs: Vec<(f64, f64)> = (0..m)
            .map(|_| (1000.0 * (1.0 + draws.uniform()), 0.5 + 4.0 * draws.uniform()))
            .collect();
        let rng = Rng::new(seed).derive("flow", 0);
        let mut fwd = FlowNet::new(&preset, m, &rng, 1.0).unwrap();
        let mut rev = FlowNet::new(&preset, m, &rng, 1.0).unwrap();
        let mut telem = Telemetry::off();
        fwd.begin_round(0.0, &mut telem);
        rev.begin_round(0.0, &mut telem);
        for j in 0..m {
            fwd.admit(j, jobs[j].0, jobs[j].1, &mut telem);
        }
        for j in (0..m).rev() {
            rev.admit(j, jobs[j].0, jobs[j].1, &mut telem);
        }
        // Same active set => bitwise the same prices, whatever the
        // admission order (all admits share one clock instant, so no
        // bits drain in between).
        for j in 0..m {
            let (pa, la) = fwd.price_of(j).unwrap();
            let (pb, lb) = rev.price_of(j).unwrap();
            assert_eq!(pa.to_bits(), pb.to_bits(), "seed {seed} client {j} price");
            assert_eq!(la, lb, "seed {seed} client {j} limited flag");
        }
        // ... and the whole drain stays bitwise identical per client.
        let mut ta = vec![f64::NAN; m];
        let mut tb = vec![f64::NAN; m];
        while let Some((t, j, _)) = fwd.next_completion(&mut telem) {
            ta[j] = t;
        }
        while let Some((t, j, _)) = rev.next_completion(&mut telem) {
            tb[j] = t;
        }
        for (j, (a, b)) in ta.iter().zip(tb.iter()).enumerate() {
            assert!(a.is_finite(), "seed {seed} client {j} never completed");
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} client {j} completion time");
        }
        assert_eq!(
            fwd.congestion_s().to_bits(),
            rev.congestion_s().to_bits(),
            "seed {seed} congestion total"
        );
    }
}

/// `flow:solo` has no shared links, so nothing is ever rate-limited:
/// through the campaign engine it must reproduce the exogenous
/// `homog:1` analytic path bit-identically (wall and round count),
/// with zero congestion on both sides.
#[test]
fn solo_topology_reproduces_the_exogenous_analytic_path_bitwise() {
    let plan_for = |scn: &str| {
        let mut cfg = ExperimentConfig::paper();
        cfg.compressor = "quant:inf".into();
        cfg.policies = vec!["fixed:2".into(), "nacfl:1".into(), "error:5.25".into()];
        cfg.seeds = (0..3).collect();
        ExperimentPlan::builder("flow-parity")
            .base(cfg)
            .scenarios(vec![ScenarioKind::parse(scn).unwrap()])
            .tiers(vec![Tier::Analytic { k_eps: 60.0 }])
            .build()
            .unwrap()
    };
    let base = execute(&plan_for("homog:1"), &ExecOptions::default(), &mut []).unwrap();
    let flow = execute(&plan_for("flow:solo"), &ExecOptions::default(), &mut []).unwrap();
    assert_eq!(base.records.len(), 3 * 3);
    assert_eq!(flow.records.len(), base.records.len());
    let by_coord = |records: &[RunRecord]| -> HashMap<(String, u64), (u64, usize, f64)> {
        records
            .iter()
            .map(|r| ((r.policy.clone(), r.seed), (r.wall.to_bits(), r.rounds, r.congestion_s)))
            .collect()
    };
    let a = by_coord(&base.records);
    let b = by_coord(&flow.records);
    assert_eq!(a.len(), 9);
    for (coord, (wall_bits, rounds, congestion)) in &a {
        let (fw, fr, fc) = b[coord];
        assert_eq!(fw, *wall_bits, "{coord:?}: wall clock diverged across paths");
        assert_eq!(fr, *rounds, "{coord:?}: round count diverged across paths");
        assert_eq!(*congestion, 0.0, "{coord:?}: analytic path reports congestion");
        assert_eq!(fc, 0.0, "{coord:?}: solo topology reports congestion");
    }
}

//! System-level policy invariants: the paper's qualitative claims,
//! checked on the analytic tier (fast, deterministic).

use nacfl::config::ExperimentConfig;
use nacfl::exp::{cell_results, execute, ExecOptions, ExperimentPlan, RunRecord, Tier};
use nacfl::metrics::{gain_vs, Summary};
use nacfl::netsim::{MarkovChain, NetworkProcess, ScenarioKind};
use nacfl::policy::{CompressionPolicy, NacFl, OraclePolicy};
use nacfl::util::rng::Rng;

fn cell(scenario: ScenarioKind, seeds: u64) -> Vec<nacfl::exp::CellResult> {
    let mut cfg = ExperimentConfig::paper();
    cfg.scenario = scenario;
    cfg.seeds = (0..seeds).collect();
    let plan = ExperimentPlan::run_cell_plan("cell", &cfg, Tier::Analytic { k_eps: 100.0 });
    // Plan-ordered records keep the per-policy times seed-ordered, which
    // the sample-path-paired gain metric below relies on.
    let summary = execute(&plan, &ExecOptions::default(), &mut []).unwrap();
    let refs: Vec<&RunRecord> = summary.records.iter().collect();
    cell_results(&refs)
}

fn mean_time(results: &[nacfl::exp::CellResult], policy_prefix: &str) -> f64 {
    Summary::of(
        &results
            .iter()
            .find(|r| r.policy.starts_with(policy_prefix))
            .unwrap()
            .times,
    )
    .mean
}

#[test]
fn nacfl_beats_every_fixed_bit_in_every_scenario() {
    // The paper's universal finding (Tables I-IV).
    for scenario in [
        ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 },
        ScenarioKind::HeterogeneousIndependent,
        ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: 4.0 },
        ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 },
    ] {
        let results = cell(scenario, 10);
        let nacfl = mean_time(&results, "nacfl");
        for bits in ["fixed:1", "fixed:2", "fixed:3"] {
            let other = mean_time(&results, bits);
            assert!(
                nacfl < other,
                "{scenario:?}: nacfl {nacfl:.3e} should beat {bits} {other:.3e}"
            );
        }
    }
}

#[test]
fn nacfl_gains_over_fixed_error_grow_with_time_correlation() {
    // Table III's headline: the NAC-FL advantage over Fixed-Error is
    // specific to temporally correlated congestion.
    let iid = cell(ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 }, 16);
    let corr = cell(ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: 16.0 }, 16);

    let gain = |results: &[nacfl::exp::CellResult]| {
        let nac = &results.iter().find(|r| r.policy.starts_with("nacfl")).unwrap().times;
        let err = &results.iter().find(|r| r.policy.starts_with("error")).unwrap().times;
        gain_vs(nac, err)
    };
    let g_iid = gain(&iid);
    let g_corr = gain(&corr);
    assert!(
        g_corr > g_iid,
        "correlated gain {g_corr:.1}% should exceed iid gain {g_iid:.1}%"
    );
    assert!(g_corr > 0.0, "NAC-FL must win under correlation ({g_corr:.1}%)");
}

#[test]
fn fixed_one_bit_is_much_worse_than_nacfl_as_in_paper() {
    // Paper Table I reports 145-881% gains over fixed-bit policies; we
    // only require the right order of magnitude (> 30%).
    let results = cell(ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 }, 12);
    let nac = &results.iter().find(|r| r.policy.starts_with("nacfl")).unwrap().times;
    let one = &results.iter().find(|r| r.policy == "fixed:1").unwrap().times;
    let g = gain_vs(nac, one);
    assert!(g > 30.0, "gain over 1-bit {g:.1}% suspiciously small");
}

#[test]
fn theorem1_nacfl_estimates_converge_to_oracle_objective() {
    // Run NAC-FL (alpha = 1, beta_n = 1/n) on a finite Markov chain and
    // compare r_hat * d_hat with the eq.-(4) optimum from the oracle.
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let m = cfg.m;
    // 6 states sampled from the homogeneous scenario's marginal.
    let mut srng = Rng::new(42);
    let states: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..m).map(|_| srng.normal_ms(1.0, 1.0).exp()).collect())
        .collect();
    let mut chain = MarkovChain::uniform_mixing(states, 0.3, Rng::new(7)).unwrap();
    let oracle = OraclePolicy::solve(&ctx, &chain);
    let opt = oracle.objective();

    let mut nac = NacFl::new(1.0);
    let mut product_at = Vec::new();
    for n in 1..=20_000usize {
        let c = chain.next_state();
        nac.choose(&ctx, &c);
        if n == 200 || n == 20_000 {
            let (r, d) = nac.estimates();
            product_at.push(r * d);
        }
    }
    let early = (product_at[0] - opt).abs() / opt;
    let late = (product_at[1] - opt).abs() / opt;
    assert!(
        late < 0.05,
        "after 20k rounds NAC-FL objective {:.4e} should be within 5% of optimum {:.4e}",
        product_at[1],
        opt
    );
    assert!(late <= early + 1e-9, "estimate error should not grow: {early} -> {late}");
}

#[test]
fn nacfl_tracks_oracle_bit_choices_on_markov_chain() {
    // Beyond the objective: after burn-in NAC-FL's per-state choices
    // should match the oracle plan on most states.
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let m = cfg.m;
    let mut srng = Rng::new(9);
    let states: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..m).map(|_| srng.normal_ms(1.0, 1.0).exp()).collect())
        .collect();
    let mut chain = MarkovChain::uniform_mixing(states.clone(), 0.3, Rng::new(3)).unwrap();
    let mut oracle = OraclePolicy::solve(&ctx, &chain);
    let mut nac = NacFl::new(1.0);
    for _ in 0..5000 {
        let c = chain.next_state();
        nac.choose(&ctx, &c);
    }
    let mut agree = 0usize;
    let mut total = 0usize;
    for s in &states {
        let nb = nac.choose(&ctx, s);
        let ob = oracle.choose(&ctx, s);
        for (a, b) in nb.iter().zip(ob.iter()) {
            total += 1;
            if (a.level as i32 - b.level as i32).abs() <= 1 {
                agree += 1;
            }
        }
    }
    let frac = agree as f64 / total as f64;
    assert!(frac > 0.8, "NAC-FL agrees with oracle on only {frac:.2} of choices");
}

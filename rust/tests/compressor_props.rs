//! Registry-wide compressor properties (ISSUE-2 satellite): every
//! registered compression family must
//!
//! 1. be **unbiased** in expectation (Assumption 8's premise),
//! 2. report a wire size that matches its actual encoded payload
//!    (exactly for fixed-size encoders, in expectation for
//!    stochastic-size ones),
//! 3. round-trip its canonical spec through `Display`/parse,
//! 4. satisfy the solver's monotonicity contract (wire size
//!    non-decreasing, variance proxy non-increasing in the level, and
//!    `max_level_within` consistent with `wire_bits`).
//!
//! Plus grammar-wide round-trip checks for policy/scenario/tier/
//! discipline specs — one spec grammar everywhere.

use nacfl::des::Discipline;
use nacfl::exp::Tier;
use nacfl::netsim::ScenarioKind;
use nacfl::policy::PolicySpec;
use nacfl::quant::{parse_compressor, registry_specs, Compressor, CompressorEnv};
use nacfl::util::rng::Rng;

const DIM: usize = 256;

fn env() -> CompressorEnv {
    CompressorEnv::paper_default(DIM)
}

fn registry() -> Vec<std::sync::Arc<dyn Compressor>> {
    registry_specs()
        .iter()
        .map(|s| parse_compressor(s, &env()).unwrap())
        .collect()
}

fn gaussian(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

#[test]
fn every_registered_compressor_round_trips_its_spec() {
    for c in registry() {
        let spec = c.spec();
        let reparsed = parse_compressor(&spec, &env()).unwrap();
        assert_eq!(reparsed.spec(), spec, "spec must round-trip: {spec}");
        // And the reparsed instance prices identically.
        let (lo, hi) = c.level_range();
        assert_eq!(reparsed.level_range(), (lo, hi));
        for l in lo..=hi {
            assert_eq!(reparsed.wire_bits(l).to_bits(), c.wire_bits(l).to_bits(), "{spec} s({l})");
            assert_eq!(
                reparsed.q_of_level(l).to_bits(),
                c.q_of_level(l).to_bits(),
                "{spec} q({l})"
            );
        }
    }
}

#[test]
fn every_registered_compressor_is_monotone_in_the_level() {
    for c in registry() {
        let spec = c.spec();
        let (lo, hi) = c.level_range();
        assert!(lo >= 1 && hi >= lo, "{spec}: degenerate range ({lo}, {hi})");
        for l in lo..hi {
            assert!(
                c.wire_bits(l + 1) >= c.wire_bits(l),
                "{spec}: wire must not shrink with the level"
            );
            assert!(
                c.q_of_level(l + 1) <= c.q_of_level(l),
                "{spec}: variance proxy must not grow with the level"
            );
        }
        assert!(c.q_of_level(lo).is_finite() && c.q_of_level(lo) >= 0.0);
    }
}

#[test]
fn max_level_within_agrees_with_wire_bits() {
    for c in registry() {
        let spec = c.spec();
        let (lo, hi) = c.level_range();
        // Below the minimum wire size: no level fits.
        assert_eq!(c.max_level_within(c.wire_bits(lo) * 0.5), None, "{spec}");
        // At each level's exact wire size, that level (or a same-size
        // larger one) fits and nothing bigger does.
        for l in lo..=hi {
            let got = c.max_level_within(c.wire_bits(l) * (1.0 + 1e-12)).unwrap();
            assert!(got >= l, "{spec}: level {l} must fit in its own wire size");
            assert!(
                c.wire_bits(got) <= c.wire_bits(l) * (1.0 + 1e-9),
                "{spec}: max_level_within returned an oversized level"
            );
        }
        // A huge budget admits the top level.
        assert_eq!(c.max_level_within(f64::INFINITY), Some(hi), "{spec}");
    }
}

#[test]
fn every_registered_compressor_is_unbiased() {
    for c in registry() {
        let spec = c.spec();
        let mut rng = Rng::new(42);
        let x = gaussian(DIM, &mut rng);
        let (lo, hi) = c.level_range();
        // Exercise the noisiest level: bias would be largest there.
        for level in [lo, hi.min(lo + 2)] {
            let trials = 8000;
            let mut sum = vec![0.0f64; DIM];
            let mut sum_sq = vec![0.0f64; DIM];
            let mut out = vec![0.0f32; DIM];
            for _ in 0..trials {
                c.compress_into(&x, level, &mut rng, &mut out);
                for ((s, s2), &o) in sum.iter_mut().zip(sum_sq.iter_mut()).zip(out.iter()) {
                    *s += o as f64;
                    *s2 += (o as f64) * (o as f64);
                }
            }
            // Self-calibrating tolerance: 6 empirical standard errors
            // (plus a float-noise floor).  Per-coordinate CLT checks are
            // restricted to coordinates with enough mass for the normal
            // approximation; the magnitude-aligned aggregate below covers
            // the tail (a biased encoder — e.g. deterministic top-k,
            // which zeroes small coordinates — shifts it decisively).
            let mut agg_bias = 0.0f64;
            let mut agg_var = 0.0f64;
            for i in 0..DIM {
                let mean = sum[i] / trials as f64;
                let var = (sum_sq[i] / trials as f64 - mean * mean).max(0.0);
                let bias = mean - x[i] as f64;
                agg_bias += bias * (x[i] as f64).signum();
                agg_var += var / trials as f64;
                if x[i].abs() >= 0.1 {
                    let tol = 6.0 * (var / trials as f64).sqrt() + 1e-4;
                    assert!(
                        bias.abs() < tol,
                        "{spec} level {level} coord {i}: mean {mean} vs {} (tol {tol})",
                        x[i]
                    );
                }
            }
            let agg_tol = 6.0 * agg_var.sqrt() + 1e-3;
            assert!(
                agg_bias.abs() < agg_tol,
                "{spec} level {level}: aggregate bias {agg_bias} (tol {agg_tol})"
            );
        }
    }
}

#[test]
fn reported_wire_size_matches_actual_payload() {
    for c in registry() {
        let spec = c.spec();
        let mut rng = Rng::new(9);
        let x = gaussian(DIM, &mut rng);
        let (lo, hi) = c.level_range();
        let mut out = vec![0.0f32; DIM];
        for level in [lo, (lo + hi) / 2, hi] {
            let trials = 300;
            let mut acc = 0.0;
            for _ in 0..trials {
                acc += c.compress_into(&x, level, &mut rng, &mut out);
            }
            let mean = acc / trials as f64;
            let model = c.wire_bits(level);
            assert!(
                (mean - model).abs() / model < 0.1,
                "{spec} level {level}: mean payload {mean} vs model {model}"
            );
        }
    }
}

#[test]
fn deterministic_encoders_report_exact_payloads() {
    for spec in ["quant:inf", "errbound:1.5625"] {
        let c = parse_compressor(spec, &env()).unwrap();
        let mut rng = Rng::new(1);
        let x = gaussian(DIM, &mut rng);
        let mut out = vec![0.0f32; DIM];
        let (lo, hi) = c.level_range();
        for level in lo..=hi {
            let actual = c.compress_into(&x, level, &mut rng, &mut out);
            assert_eq!(
                actual.to_bits(),
                c.wire_bits(level).to_bits(),
                "{spec} level {level}"
            );
        }
    }
}

// ---- unified spec grammar: round-trip Display everywhere -------------

#[test]
fn policy_specs_round_trip() {
    for s in ["nacfl:2", "nacfl:1", "fixed:1", "fixed:32", "error:5.25", "oracle:8"] {
        let p = PolicySpec::parse(s).unwrap();
        assert_eq!(p.to_string(), s);
        assert_eq!(PolicySpec::parse(&p.to_string()).unwrap(), p);
    }
}

#[test]
fn scenario_specs_round_trip() {
    for s in ["homog:1", "homog:2.5", "heterog", "perf:4", "part:16"] {
        let k = ScenarioKind::parse(s).unwrap();
        assert_eq!(k.to_string(), s);
        assert_eq!(ScenarioKind::parse(&k.to_string()).unwrap(), k);
    }
}

#[test]
fn tier_specs_round_trip() {
    for s in ["ml", "sim:100", "sim:2.5"] {
        let t = Tier::parse(s).unwrap();
        assert_eq!(t.to_string(), s);
        assert_eq!(Tier::parse(&t.to_string()).unwrap(), t);
    }
}

#[test]
fn discipline_specs_round_trip() {
    for s in ["sync", "semi-sync:7", "async:0.5", "async:1"] {
        let d = Discipline::parse(s).unwrap();
        assert_eq!(d.to_string(), s);
        assert_eq!(Discipline::parse(&d.to_string()).unwrap(), d);
    }
}

#[test]
fn compressor_specs_round_trip_via_config_strings() {
    for s in registry_specs() {
        let c = parse_compressor(&s, &env()).unwrap();
        assert_eq!(c.spec(), s);
    }
}

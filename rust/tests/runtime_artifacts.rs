//! Integration: the AOT artifacts load, compile and execute through PJRT,
//! and the XLA engine agrees numerically with the pure-rust engine.
//!
//! All tests skip (with a notice) when `artifacts/` has not been built —
//! run `make artifacts` first for full coverage.

use nacfl::fl::engine::{ComputeEngine, RustEngine, XlaEngine};
use nacfl::model::{Mlp, MlpDims};
use nacfl::runtime::{dims, Runtime};
use nacfl::util::rng::Rng;

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn artifacts_ready() -> bool {
    let ok = Runtime::artifacts_present(artifact_dir());
    if !ok {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
    }
    ok
}

#[test]
fn artifacts_load_and_compile() {
    if !artifacts_ready() {
        return;
    }
    let mut rt = Runtime::cpu(artifact_dir()).unwrap();
    rt.load_all().unwrap();
}

#[test]
fn xla_engine_matches_rust_engine_end_to_end() {
    if !artifacts_ready() {
        return;
    }
    let mut xe = XlaEngine::new(&artifact_dir()).unwrap();
    let mut re = RustEngine::new();
    let d = xe.dims();
    let mut rng = Rng::new(99);
    let mlp = Mlp::new(MlpDims::paper());
    let w = mlp.init_params(&mut rng);
    let xs: Vec<f32> = (0..d.tau * d.batch * d.d_in).map(|_| rng.uniform_f32()).collect();
    let ys: Vec<i32> = (0..d.tau * d.batch).map(|_| rng.below(10) as i32).collect();

    // local_round parity
    let ux = xe.local_round(&w, &xs, &ys, 0.07).unwrap();
    let ur = re.local_round(&w, &xs, &ys, 0.07).unwrap();
    let scale = ux.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-6);
    let worst = ux
        .iter()
        .zip(ur.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        worst < 5e-3 * scale.max(1.0),
        "local_round divergence {worst} (scale {scale})"
    );

    // quantize parity: identical uniforms => identical grids
    let mut u = vec![0.0f32; d.p];
    rng.fill_uniform_f32(&mut u);
    let (qx, nx) = xe.quantize(&ux, 7.0, &u).unwrap();
    let (qr, nr) = re.quantize(&ux, 7.0, &u).unwrap();
    assert_eq!(nx, nr, "norms differ");
    let nbad = qx.iter().zip(qr.iter()).filter(|(a, b)| a != b).count();
    assert_eq!(nbad, 0, "{nbad} quantized coords differ");

    // global_step parity (up to FMA-contraction differences in XLA)
    let wx = xe.global_step(&w, &qx, 0.05).unwrap();
    let wr = re.global_step(&w, &qr, 0.05).unwrap();
    let worst_gs = wx
        .iter()
        .zip(wr.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(worst_gs <= 1e-6, "global_step divergence {worst_gs}");

    // eval parity
    let ex: Vec<f32> = (0..d.eval_chunk * d.d_in).map(|_| rng.uniform_f32()).collect();
    let ey: Vec<i32> = (0..d.eval_chunk).map(|_| rng.below(10) as i32).collect();
    let (lx, cx) = xe.eval_chunk(&w, &ex, &ey).unwrap();
    let (lr, cr) = re.eval_chunk(&w, &ex, &ey).unwrap();
    assert_eq!(cx, cr, "correct-count mismatch");
    assert!((lx - lr).abs() < 1e-2 * lr.abs().max(1.0), "loss {lx} vs {lr}");
}

#[test]
fn quantize_graph_handles_all_bitwidths() {
    if !artifacts_ready() {
        return;
    }
    let mut xe = XlaEngine::new(&artifact_dir()).unwrap();
    let d = xe.dims();
    let mut rng = Rng::new(5);
    let v: Vec<f32> = (0..d.p).map(|_| rng.normal() as f32).collect();
    let mut u = vec![0.0f32; d.p];
    rng.fill_uniform_f32(&mut u);
    for b in [1u8, 2, 3, 8, 16, 32] {
        let s = nacfl::quant::levels(b);
        let (dq, norm) = xe.quantize(&v, s, &u).unwrap();
        assert!(norm > 0.0);
        // grid property — only meaningful while s fits f32's mantissa
        if b <= 16 {
            for (i, &q) in dq.iter().enumerate().step_by(9973) {
                let k = (q.abs() as f64) * s / norm as f64;
                assert!((k - k.round()).abs() < 1e-2, "b={b} coord {i}: k={k}");
            }
        }
        // error bounded by one step (+ f32 rounding slack at high b)
        let step = norm as f64 * (1.0 / s + 1e-5);
        let worst = v
            .iter()
            .zip(dq.iter())
            .map(|(&a, &b)| ((a - b) as f64).abs())
            .fold(0.0, f64::max);
        assert!(worst <= step, "b={b}: err {worst} > step {step}");
    }
}

#[test]
fn dims_match_manifest() {
    // The rust-side constants must agree with what python lowered.
    let manifest = format!("{}/manifest.json", artifact_dir());
    let Ok(text) = std::fs::read_to_string(&manifest) else {
        eprintln!("SKIP: no manifest");
        return;
    };
    // crude but dependency-free: check the _dims block values.
    for (key, val) in [
        ("\"P\"", dims::P.to_string()),
        ("\"TAU\"", dims::TAU.to_string()),
        ("\"BATCH\"", dims::BATCH.to_string()),
        ("\"EVAL_CHUNK\"", dims::EVAL_CHUNK.to_string()),
    ] {
        let needle = format!("{key}: {val}");
        assert!(
            text.contains(&needle),
            "manifest disagrees on {key} (wanted `{needle}`)"
        );
    }
}

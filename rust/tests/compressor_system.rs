//! End-to-end regression per compressor family (ISSUE-2 acceptance):
//! `topk` and `errbound` must drive the full paper roster through BOTH
//! engine routes — the analytic closed form (`nacfl exp`/`sim` cells)
//! and the DES path (a disciplines-axis plan, the `nacfl des` shape) —
//! converging and preserving the tiers' parity invariants; and the
//! spec-built `oracle:<states>` policy must run inside a roster like
//! any other policy (Theorem-1 preset).  Everything routes through
//! `exp::exec::execute` (the legacy drivers are gone).

use nacfl::config::ExperimentConfig;
use nacfl::des::Discipline;
use nacfl::exp::{
    campaign_table, cell_results, execute, table_cells, table_for, CellResult, ExecOptions,
    ExperimentPlan, RunRecord, Tier,
};
use nacfl::metrics::Summary;
use nacfl::netsim::ScenarioKind;

fn cfg_for(compressor: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper();
    cfg.compressor = compressor.into();
    cfg.seeds = (0..6).collect();
    cfg.validate().unwrap();
    cfg
}

/// Engine run -> legacy-shaped per-policy results (plan order).
fn engine_cell(cfg: &ExperimentConfig, tier: Tier, threads: usize) -> Vec<CellResult> {
    let plan = ExperimentPlan::run_cell_plan("cell", cfg, tier);
    let summary = execute(&plan, &ExecOptions::with_threads(threads), &mut []).unwrap();
    let refs: Vec<&RunRecord> = summary.records.iter().collect();
    cell_results(&refs)
}

/// The analytic `nacfl exp` path: full roster, threaded engine,
/// rendered table — once per new compressor family.
#[test]
fn topk_and_errbound_run_the_analytic_exp_path_end_to_end() {
    for compressor in ["topk:0.05", "errbound:1.5625"] {
        let cfg = cfg_for(compressor);
        let tier = Tier::Analytic { k_eps: 60.0 };
        let results = engine_cell(&cfg, tier, 4);
        assert_eq!(results.len(), 5, "{compressor}: full paper roster");
        for r in &results {
            assert_eq!(r.times.len(), cfg.seeds.len());
            assert!(
                r.times.iter().all(|t| t.is_finite() && *t > 0.0),
                "{compressor} {}: non-finite time-to-target",
                r.policy
            );
            // Convergence, not budget exhaustion.
            assert!(
                r.rounds.iter().all(|&n| n > 0 && n < 10_000_000),
                "{compressor} {}: hit the round cap",
                r.policy
            );
        }
        // Adaptivity must still pay: NAC-FL beats the worst fixed level.
        let nacfl = Summary::of(&results[4].times).mean;
        let worst_fixed = results[..3]
            .iter()
            .map(|r| Summary::of(&r.times).mean)
            .fold(0.0f64, f64::max);
        assert!(
            nacfl < worst_fixed,
            "{compressor}: nacfl {nacfl:.3e} vs worst fixed {worst_fixed:.3e}"
        );
        // And the rendered table still builds (gain row present).
        let table = table_for(&format!("{compressor} cell"), &results).unwrap();
        assert!(table.render().contains("Gain"));

        // Thread-count parity holds for the new families too.
        let seq = engine_cell(&cfg, tier, 1);
        for (a, b) in seq.iter().zip(results.iter()) {
            assert_eq!(a.times, b.times, "{compressor} {}: grid parity", a.policy);
        }
    }
}

/// The DES path: a disciplines-axis plan per family (the `nacfl des`
/// shape), through the same engine.
#[test]
fn topk_and_errbound_run_the_des_sweep_end_to_end() {
    for compressor in ["topk:0.05", "errbound:1.5625"] {
        let mut cfg = cfg_for(compressor);
        cfg.policies = vec!["fixed:2".into(), "nacfl:1".into()];
        cfg.seeds = (0..3).collect();
        cfg.scenario = ScenarioKind::HeterogeneousIndependent;
        let plan = ExperimentPlan::builder(format!("des {compressor}"))
            .base(cfg)
            .tiers(vec![Tier::Analytic { k_eps: 40.0 }])
            .disciplines(vec![
                Discipline::Sync,
                Discipline::SemiSync { k: 7 },
                Discipline::Async { staleness_exp: 0.5 },
            ])
            .build()
            .unwrap();
        let summary = execute(&plan, &ExecOptions::with_threads(4), &mut []).unwrap();
        assert_eq!(summary.records.len(), 3 * 2 * 3, "{compressor}");
        for r in &summary.records {
            assert!(r.converged, "{compressor} {} {}: unconverged", r.discipline, r.policy);
            assert!(r.wall > 0.0 && r.aggregations > 0);
        }
        let table = campaign_table("des", &plan, &summary.records).unwrap();
        assert!(table.render().contains("semi-sync:7"));
    }
}

/// Fault-free sync DES must reproduce the analytic tier for the new
/// families exactly as it does for the quantizer (shared float path).
#[test]
fn sync_des_parity_holds_for_new_compressor_families() {
    use nacfl::des::{simulate_des, DesConfig};
    use nacfl::policy::{PolicyEnv, PolicySpec};
    use nacfl::sim::simulate;
    use nacfl::util::rng::Rng;
    for compressor in ["topk:0.1", "errbound:1.5625"] {
        let cfg = cfg_for(compressor);
        let ctx = cfg.policy_ctx();
        for seed in [0u64, 3] {
            let env = PolicyEnv::for_cell(&ctx, cfg.scenario, cfg.m, seed);
            let mut p1 = PolicySpec::parse("nacfl:1").unwrap().build(&env).unwrap();
            let mut p2 = PolicySpec::parse("nacfl:1").unwrap().build(&env).unwrap();
            let mut n1 = cfg.congestion_process(seed).unwrap();
            let mut n2 = cfg.congestion_process(seed).unwrap();
            let r_sim = simulate(&ctx, p1.as_mut(), &mut n1, 50.0, 1_000_000);
            let des = DesConfig::new(Discipline::Sync, 50.0);
            let r_des = simulate_des(&ctx, p2.as_mut(), &mut n2, &des, Rng::new(7)).unwrap();
            assert_eq!(r_des.rounds, r_sim.rounds, "{compressor} seed {seed}");
            let rel = (r_des.wall - r_sim.wall).abs() / r_sim.wall;
            assert!(rel <= 1e-12, "{compressor} seed {seed}: rel {rel}");
        }
    }
}

/// The Theorem-1 preset: `oracle:8` built from its spec inside a normal
/// roster, through the same engine path as everything else.
#[test]
fn oracle_spec_runs_inside_the_theorem1_roster() {
    let base = {
        let mut c = ExperimentConfig::paper();
        c.seeds = (0..3).collect();
        c
    };
    let cells = table_cells("theorem1", &base).unwrap();
    let (label, cfg) = &cells[0];
    assert!(label.contains("Theorem 1"));
    let results = engine_cell(cfg, Tier::Analytic { k_eps: 60.0 }, 4);
    assert_eq!(results.len(), 6);
    let oracle = results.iter().find(|r| r.policy.starts_with("oracle")).unwrap();
    assert!(oracle.times.iter().all(|t| t.is_finite() && *t > 0.0));
    // Determinism under threading: oracle cells must match sequential.
    let seq = engine_cell(cfg, Tier::Analytic { k_eps: 60.0 }, 1);
    let oracle_seq = seq.iter().find(|r| r.policy.starts_with("oracle")).unwrap();
    assert_eq!(oracle.times, oracle_seq.times);
    // The gain table renders with the oracle column present.
    let table = table_for(label, &results).unwrap().render();
    assert!(table.contains("oracle:8"));
}

/// Legacy guard: the default config still registers the paper quantizer
/// and the roster's analytic numbers remain deterministic across thread
/// counts (the bit-identity regression every redesign must preserve).
#[test]
fn default_compressor_is_the_paper_quantizer_and_tables_are_stable() {
    let cfg = {
        let mut c = ExperimentConfig::paper();
        c.seeds = (0..8).collect();
        c
    };
    assert_eq!(cfg.compressor, "quant:inf");
    assert_eq!(cfg.policy_ctx().compressor.spec(), "quant:inf");
    let tier = Tier::Analytic { k_eps: 80.0 };
    let seq = engine_cell(&cfg, tier, 1);
    for threads in [2usize, 8] {
        let par = engine_cell(&cfg, tier, threads);
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.times, b.times, "{} with {threads} threads", a.policy);
            assert_eq!(a.rounds, b.rounds);
        }
    }
}

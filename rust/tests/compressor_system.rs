//! End-to-end regression per compressor family (ISSUE-2 acceptance):
//! `topk` and `errbound` must drive the full paper roster through BOTH
//! tiers — the analytic experiment path (`nacfl exp`/`sim`, i.e.
//! `run_cell_parallel`) and the DES path (`nacfl des`, i.e.
//! `run_sweep`) — converging and preserving the tiers' parity
//! invariants; and the spec-built `oracle:<states>` policy must run
//! inside a roster like any other policy (Theorem-1 preset).

use nacfl::config::ExperimentConfig;
use nacfl::des::{Discipline, FaultModel};
use nacfl::exp::{
    run_cell, run_cell_parallel, run_sweep, sweep_table, table_cells, table_for, SweepSpec, Tier,
};
use nacfl::metrics::Summary;
use nacfl::netsim::ScenarioKind;

fn cfg_for(compressor: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper();
    cfg.compressor = compressor.into();
    cfg.seeds = (0..6).collect();
    cfg.validate().unwrap();
    cfg
}

/// The analytic `nacfl exp` path: full roster, parallel grid, rendered
/// table — once per new compressor family.
#[test]
fn topk_and_errbound_run_the_analytic_exp_path_end_to_end() {
    for compressor in ["topk:0.05", "errbound:1.5625"] {
        let cfg = cfg_for(compressor);
        let tier = Tier::Analytic { k_eps: 60.0 };
        let results = run_cell_parallel(&cfg, tier, 4, |_, _, _| {}).unwrap();
        assert_eq!(results.len(), 5, "{compressor}: full paper roster");
        for r in &results {
            assert_eq!(r.times.len(), cfg.seeds.len());
            assert!(
                r.times.iter().all(|t| t.is_finite() && *t > 0.0),
                "{compressor} {}: non-finite time-to-target",
                r.policy
            );
            // Convergence, not budget exhaustion.
            assert!(
                r.rounds.iter().all(|&n| n > 0 && n < 10_000_000),
                "{compressor} {}: hit the round cap",
                r.policy
            );
        }
        // Adaptivity must still pay: NAC-FL beats the worst fixed level.
        let nacfl = Summary::of(&results[4].times).mean;
        let worst_fixed = results[..3]
            .iter()
            .map(|r| Summary::of(&r.times).mean)
            .fold(0.0f64, f64::max);
        assert!(
            nacfl < worst_fixed,
            "{compressor}: nacfl {nacfl:.3e} vs worst fixed {worst_fixed:.3e}"
        );
        // And the rendered table still builds (gain row present).
        let table = table_for(&format!("{compressor} cell"), &results).unwrap();
        assert!(table.render().contains("Gain"));

        // Parallel grid parity holds for the new families too.
        let seq = run_cell(&cfg, tier, |_, _, _| {}).unwrap();
        for (a, b) in seq.iter().zip(results.iter()) {
            assert_eq!(a.times, b.times, "{compressor} {}: grid parity", a.policy);
        }
    }
}

/// The `nacfl des` path: sweep all three disciplines per family.
#[test]
fn topk_and_errbound_run_the_des_sweep_end_to_end() {
    for compressor in ["topk:0.05", "errbound:1.5625"] {
        let cfg = cfg_for(compressor);
        let ctx = cfg.policy_ctx();
        let spec = SweepSpec {
            m: cfg.m,
            scenarios: vec![ScenarioKind::HeterogeneousIndependent],
            disciplines: vec![
                Discipline::Sync,
                Discipline::SemiSync { k: 7 },
                Discipline::Async { staleness_exp: 0.5 },
            ],
            policies: vec!["fixed:2".into(), "nacfl:1".into()],
            seeds: (0..3).collect(),
            faults: FaultModel::none(),
            k_eps: 40.0,
            max_rounds: 500_000,
        };
        let cells = run_sweep(&ctx, &spec, 4).unwrap();
        assert_eq!(cells.len(), 3 * 2 * 3, "{compressor}");
        for c in &cells {
            assert!(c.result.converged, "{compressor} {} {}: unconverged", c.discipline, c.policy);
            assert!(c.result.wall > 0.0 && c.result.aggregations > 0);
        }
        let table = sweep_table("des", &spec, &cells).unwrap();
        assert!(table.render().contains("semi-sync:7"));
    }
}

/// Fault-free sync DES must reproduce the analytic tier for the new
/// families exactly as it does for the quantizer (shared float path).
#[test]
fn sync_des_parity_holds_for_new_compressor_families() {
    use nacfl::des::{simulate_des, DesConfig};
    use nacfl::policy::{PolicyEnv, PolicySpec};
    use nacfl::sim::simulate;
    use nacfl::util::rng::Rng;
    for compressor in ["topk:0.1", "errbound:1.5625"] {
        let cfg = cfg_for(compressor);
        let ctx = cfg.policy_ctx();
        for seed in [0u64, 3] {
            let env = PolicyEnv::for_cell(&ctx, cfg.scenario, cfg.m, seed);
            let mut p1 = PolicySpec::parse("nacfl:1").unwrap().build(&env).unwrap();
            let mut p2 = PolicySpec::parse("nacfl:1").unwrap().build(&env).unwrap();
            let mut n1 = cfg.congestion_process(seed).unwrap();
            let mut n2 = cfg.congestion_process(seed).unwrap();
            let r_sim = simulate(&ctx, p1.as_mut(), &mut n1, 50.0, 1_000_000);
            let des = DesConfig::new(Discipline::Sync, 50.0);
            let r_des = simulate_des(&ctx, p2.as_mut(), &mut n2, &des, Rng::new(7)).unwrap();
            assert_eq!(r_des.rounds, r_sim.rounds, "{compressor} seed {seed}");
            let rel = (r_des.wall - r_sim.wall).abs() / r_sim.wall;
            assert!(rel <= 1e-12, "{compressor} seed {seed}: rel {rel}");
        }
    }
}

/// The Theorem-1 preset: `oracle:8` built from its spec inside a normal
/// roster, through the same analytic cell path as everything else.
#[test]
fn oracle_spec_runs_inside_the_theorem1_roster() {
    let base = {
        let mut c = ExperimentConfig::paper();
        c.seeds = (0..3).collect();
        c
    };
    let cells = table_cells("theorem1", &base).unwrap();
    let (label, cfg) = &cells[0];
    assert!(label.contains("Theorem 1"));
    let results = run_cell_parallel(cfg, Tier::Analytic { k_eps: 60.0 }, 4, |_, _, _| {}).unwrap();
    assert_eq!(results.len(), 6);
    let oracle = results.iter().find(|r| r.policy.starts_with("oracle")).unwrap();
    assert!(oracle.times.iter().all(|t| t.is_finite() && *t > 0.0));
    // Determinism under threading: oracle cells must match sequential.
    let seq = run_cell(cfg, Tier::Analytic { k_eps: 60.0 }, |_, _, _| {}).unwrap();
    let oracle_seq = seq.iter().find(|r| r.policy.starts_with("oracle")).unwrap();
    assert_eq!(oracle.times, oracle_seq.times);
    // The gain table renders with the oracle column present.
    let table = table_for(label, &results).unwrap().render();
    assert!(table.contains("oracle:8"));
}

/// Legacy guard: the default config still registers the paper quantizer
/// and the roster's analytic numbers remain deterministic across
/// executors (the bit-identity regression the redesign must preserve).
#[test]
fn default_compressor_is_the_paper_quantizer_and_tables_are_stable() {
    let cfg = {
        let mut c = ExperimentConfig::paper();
        c.seeds = (0..8).collect();
        c
    };
    assert_eq!(cfg.compressor, "quant:inf");
    assert_eq!(cfg.policy_ctx().compressor.spec(), "quant:inf");
    let tier = Tier::Analytic { k_eps: 80.0 };
    let seq = run_cell(&cfg, tier, |_, _, _| {}).unwrap();
    for threads in [2usize, 8] {
        let par = run_cell_parallel(&cfg, tier, threads, |_, _, _| {}).unwrap();
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.times, b.times, "{} with {threads} threads", a.policy);
            assert_eq!(a.rounds, b.rounds);
        }
    }
}

//! System tests for the DES tier and the parallel experiment grid.
//!
//! * `sync` discipline parity: on paired sample paths the DES engine
//!   reproduces the analytic tier's wall clock within 1e-6 relative
//!   tolerance (in fact bit-exactly — same float path) across scenarios
//!   and policies.
//! * `semi-sync:K` strictly shortens mean round duration vs `sync` under
//!   the heterogeneous-independent scenario with straggler injection.
//! * The work-stealing engine produces bit-identical tables under any
//!   thread count for a fixed seed set.

use nacfl::config::ExperimentConfig;
use nacfl::des::{simulate_des, DesConfig, Discipline, FaultModel};
use nacfl::exp::{execute, ExecOptions, ExperimentPlan, TableSink, Tier};
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::sim::simulate;
use nacfl::util::rng::Rng;

const K_EPS: f64 = 100.0;

fn scenarios() -> Vec<ScenarioKind> {
    vec![
        ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 },
        ScenarioKind::HeterogeneousIndependent,
        ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: 4.0 },
        ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 },
    ]
}

#[test]
fn sync_discipline_reproduces_analytic_wall_clock() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    for kind in scenarios() {
        for spec in ["fixed:1", "fixed:3", "error:5.25", "nacfl:1"] {
            for seed in [0u64, 7, 42] {
                let scenario = Scenario::new(kind, cfg.m);
                // Paired sample paths: same derived stream for both tiers.
                let mut proc_a = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
                let mut proc_b = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
                let mut pol_a = parse_policy(spec).unwrap();
                let mut pol_b = parse_policy(spec).unwrap();

                let r_sim = simulate(&ctx, pol_a.as_mut(), &mut proc_a, K_EPS, 10_000_000);
                let des = DesConfig::new(Discipline::Sync, K_EPS);
                let r_des =
                    simulate_des(&ctx, pol_b.as_mut(), &mut proc_b, &des, Rng::new(1)).unwrap();

                let rel = (r_des.wall - r_sim.wall).abs() / r_sim.wall.abs().max(1e-300);
                assert!(
                    rel <= 1e-6,
                    "{} {spec} seed {seed}: DES wall {:.12e} vs sim {:.12e} (rel {rel:.3e})",
                    kind.label(),
                    r_des.wall,
                    r_sim.wall
                );
                assert_eq!(
                    r_des.rounds, r_sim.rounds,
                    "{} {spec} seed {seed}: stopping round mismatch",
                    kind.label()
                );
            }
        }
    }
}

#[test]
fn semi_sync_strictly_reduces_mean_round_duration_under_stragglers() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let faults = FaultModel::none().with_stragglers(cfg.m, &[8, 9], 8.0);
    let mut improved = 0usize;
    let seeds: Vec<u64> = (0..6).collect();
    for &seed in &seeds {
        let scenario = Scenario::new(ScenarioKind::HeterogeneousIndependent, cfg.m);
        let mut proc_sync = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
        let mut proc_semi = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
        let mut pol_sync = parse_policy("fixed:2").unwrap();
        let mut pol_semi = parse_policy("fixed:2").unwrap();

        let sync_cfg = DesConfig::new(Discipline::Sync, K_EPS).with_faults(faults.clone());
        let semi_cfg =
            DesConfig::new(Discipline::SemiSync { k: 7 }, K_EPS).with_faults(faults.clone());
        let r_sync =
            simulate_des(&ctx, pol_sync.as_mut(), &mut proc_sync, &sync_cfg, Rng::new(0)).unwrap();
        let r_semi =
            simulate_des(&ctx, pol_semi.as_mut(), &mut proc_semi, &semi_cfg, Rng::new(0)).unwrap();

        assert!(
            r_semi.mean_round_duration() < r_sync.mean_round_duration(),
            "seed {seed}: semi-sync mean round {:.3e} !< sync {:.3e}",
            r_semi.mean_round_duration(),
            r_sync.mean_round_duration()
        );
        assert!(r_semi.late_updates > 0, "seed {seed}: no late updates recorded");
        improved += 1;
    }
    assert_eq!(improved, seeds.len());
}

#[test]
fn async_discipline_beats_sync_under_extreme_stragglers() {
    // With one client 50x slower, sync pays the straggler every round;
    // async keeps aggregating the other nine and wins on wall clock
    // despite its staleness-discounted progress accounting.
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let faults = FaultModel::none().with_stragglers(cfg.m, &[0], 50.0);
    let mut wins = 0usize;
    let seeds = [0u64, 1, 2];
    for &seed in &seeds {
        let scenario = Scenario::new(ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 }, cfg.m);
        let mut proc_sync = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
        let mut proc_async = scenario.process(Rng::new(seed).derive("net", 0)).unwrap();
        let mut pol_sync = parse_policy("fixed:2").unwrap();
        let mut pol_async = parse_policy("fixed:2").unwrap();
        let sync_cfg = DesConfig::new(Discipline::Sync, K_EPS).with_faults(faults.clone());
        let async_cfg = DesConfig::new(Discipline::Async { staleness_exp: 0.5 }, K_EPS)
            .with_faults(faults.clone());
        let r_sync =
            simulate_des(&ctx, pol_sync.as_mut(), &mut proc_sync, &sync_cfg, Rng::new(0)).unwrap();
        let r_async =
            simulate_des(&ctx, pol_async.as_mut(), &mut proc_async, &async_cfg, Rng::new(0))
                .unwrap();
        assert!(r_sync.converged && r_async.converged);
        if r_async.wall < r_sync.wall {
            wins += 1;
        }
    }
    assert!(
        wins >= 2,
        "async should beat sync under a 50x straggler on most seeds (won {wins}/{})",
        seeds.len()
    );
}

#[test]
fn policies_run_unmodified_across_disciplines() {
    // The PolicyCtx hook: every roster policy drives every discipline
    // without modification and converges.
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    for spec in ["fixed:1", "fixed:2", "fixed:3", "error:5.25", "nacfl:1"] {
        for d in [
            Discipline::Sync,
            Discipline::SemiSync { k: 7 },
            Discipline::Async { staleness_exp: 0.5 },
        ] {
            let scenario = Scenario::new(ScenarioKind::HeterogeneousIndependent, cfg.m);
            let mut process = scenario.process(Rng::new(3).derive("net", 0)).unwrap();
            let mut policy = parse_policy(spec).unwrap();
            let des = DesConfig::new(d, 60.0);
            let r = simulate_des(&ctx, policy.as_mut(), &mut process, &des, Rng::new(5)).unwrap();
            assert!(r.converged, "{spec} under {} did not converge", d.label());
            assert!(r.wall > 0.0 && r.aggregations > 0);
        }
    }
}

#[test]
fn engine_tables_are_bit_identical_under_any_thread_count() {
    let mut cfg = ExperimentConfig::paper();
    cfg.seeds = (0..8).collect();
    let tier = Tier::Analytic { k_eps: 80.0 };
    let plan = ExperimentPlan::run_cell_plan("parity", &cfg, tier);
    let run = |threads: usize| {
        let mut sink = TableSink::new(Some("parity".to_string()));
        let summary =
            execute(&plan, &ExecOptions::with_threads(threads), &mut [&mut sink]).unwrap();
        (summary.records, sink.tables[0].render())
    };
    let (seq, seq_table) = run(1);
    for threads in [2usize, 4, 8] {
        let (par, par_table) = run(threads);
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.key(), b.key());
            assert_eq!(
                a.wall.to_bits(),
                b.wall.to_bits(),
                "{} with {threads} threads",
                a.key()
            );
            assert_eq!(a.rounds, b.rounds);
        }
        assert_eq!(seq_table, par_table, "{threads}-thread table differs from sequential");
    }
}

//! System tests for distributed campaign execution (ISSUE-5):
//!
//! * shard workers jointly cover the plan, disjointly, and `merge`
//!   regenerates tables **byte-identical** to a single-machine run —
//!   including after killing and resuming one shard mid-campaign;
//! * the plan-identity ledger header rejects resuming or merging a
//!   different campaign;
//! * work stealing reclaims runs whose claims expired (dead workers)
//!   while respecting live foreign leases;
//! * overlapping ledgers dedup by coordinate key and a merged ledger is
//!   itself a fully-resumable single-machine ledger.

use nacfl::config::ExperimentConfig;
use nacfl::exp::dist::now_unix;
use nacfl::exp::{
    build_tables, execute, merge_ledgers, read_dist_ledger, write_ledger, ClaimRecord,
    ExecOptions, ExperimentPlan, PlanHeader, ShardSpec, Tier,
};

fn temp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nacfl_dist_sys_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// 12 analytic runs (2 policies x 3 seeds x 2 disciplines) — small
/// enough to be fast, mixed enough to route through both the closed
/// form and the DES engine.
fn test_plan() -> ExperimentPlan {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..3).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    ExperimentPlan::builder("dist demo")
        .base(base)
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .disciplines(vec![
            nacfl::des::Discipline::Sync,
            nacfl::des::Discipline::SemiSync { k: 7 },
        ])
        .build()
        .unwrap()
}

fn opts_for(ledger: &str, shard: ShardSpec) -> ExecOptions {
    ExecOptions {
        threads: 2,
        ledger: Some(ledger.to_string()),
        shard,
        ..Default::default()
    }
}

#[test]
fn sharded_workers_merge_bit_identically_to_a_single_machine_run() {
    let plan = test_plan();
    let n = plan.n_runs();

    // Single-machine reference: one worker, one ledger, full coverage.
    let single = temp("single");
    let _ = std::fs::remove_file(&single);
    let full = execute(&plan, &opts_for(&single, ShardSpec::solo()), &mut []).unwrap();
    assert_eq!(full.records.len(), n);
    let single_tables: Vec<String> = build_tables(None, &full.records)
        .unwrap()
        .iter()
        .map(|t| t.render())
        .collect();

    // Fleet: two workers, separate ledgers, one hash shard each.
    let la = temp("w0");
    let lb = temp("w1");
    let _ = std::fs::remove_file(&la);
    let _ = std::fs::remove_file(&lb);
    let a = execute(&plan, &opts_for(&la, ShardSpec::parse("0/2").unwrap()), &mut []).unwrap();
    let b = execute(&plan, &opts_for(&lb, ShardSpec::parse("1/2").unwrap()), &mut []).unwrap();
    assert!(a.n_skipped > 0 && b.n_skipped > 0, "both shards must be partial");
    assert_eq!(a.records.len() + b.records.len(), n, "disjoint and exhaustive");

    // Merge the fleet's ledgers against the plan: complete coverage and
    // byte-identical paper tables.
    let merged = merge_ledgers(&[&la, &lb], Some(&plan)).unwrap();
    assert!(merged.complete(), "missing: {:?}", merged.missing);
    assert_eq!(merged.n_duplicates, 0);
    for (x, y) in full.records.iter().zip(merged.records.iter()) {
        assert_eq!(x.key(), y.key(), "merge must return plan order");
        assert_eq!(x.wall.to_bits(), y.wall.to_bits(), "{}", x.key());
    }
    let merged_tables: Vec<String> = build_tables(None, &merged.records)
        .unwrap()
        .iter()
        .map(|t| t.render())
        .collect();
    assert_eq!(merged_tables, single_tables, "fleet tables == single-machine tables");

    // A written-out merged ledger is a fully-resumable single-machine
    // ledger: rerunning the plan against it executes nothing.
    let mpath = temp("merged");
    write_ledger(&mpath, merged.header.as_ref(), &merged.records).unwrap();
    let resumed = execute(&plan, &opts_for(&mpath, ShardSpec::solo()), &mut []).unwrap();
    assert_eq!(resumed.n_cached, n);
    assert_eq!(resumed.n_executed, 0);

    for p in [&single, &la, &lb, &mpath] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn killed_shard_resumes_and_merged_tables_stay_bit_identical() {
    let plan = test_plan();
    let n = plan.n_runs();
    let single = temp("kill_single");
    let la = temp("kill_w0");
    let lb = temp("kill_w1");
    for p in [&single, &la, &lb] {
        let _ = std::fs::remove_file(p);
    }

    let full = execute(&plan, &opts_for(&single, ShardSpec::solo()), &mut []).unwrap();
    let shard0 = ShardSpec::parse("0/2").unwrap();
    let shard1 = ShardSpec::parse("1/2").unwrap();
    let a = execute(&plan, &opts_for(&la, shard0), &mut []).unwrap();
    execute(&plan, &opts_for(&lb, shard1), &mut []).unwrap();
    assert!(a.records.len() >= 2, "shard 0 needs >= 2 runs for the kill");

    // Kill worker 0 mid-campaign: the header, its claim lines, one
    // complete run and a torn half-written run survive on disk.
    let text = std::fs::read_to_string(&la).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let run_idx: Vec<usize> = lines
        .iter()
        .enumerate()
        .filter(|(_, l)| !l.contains("\"kind\":"))
        .map(|(i, _)| i)
        .collect();
    assert!(run_idx.len() >= 2, "need two run lines to tear one");
    let mut torn = lines[..=run_idx[0]].join("\n");
    torn.push('\n');
    let second = lines[run_idx[1]];
    torn.push_str(&second[..second.len() / 2]);
    std::fs::write(&la, &torn).unwrap();

    // Before the resume, the merge reports exactly the lost runs.
    let gap = merge_ledgers(&[&la, &lb], Some(&plan)).unwrap();
    assert_eq!(gap.missing.len(), a.records.len() - 1, "torn runs are the gap");

    // The restarted worker resumes its shard: 1 cached, rest re-run.
    let resumed = execute(&plan, &opts_for(&la, shard0), &mut []).unwrap();
    assert_eq!(resumed.n_cached, 1);
    assert_eq!(resumed.n_executed, a.records.len() - 1);

    // And the fleet still merges byte-identically.
    let merged = merge_ledgers(&[&la, &lb], Some(&plan)).unwrap();
    assert!(merged.complete());
    assert_eq!(merged.records.len(), n);
    for (x, y) in full.records.iter().zip(merged.records.iter()) {
        assert_eq!(x.wall.to_bits(), y.wall.to_bits(), "{}", x.key());
    }
    let single_tables: Vec<String> =
        build_tables(None, &full.records).unwrap().iter().map(|t| t.render()).collect();
    let merged_tables: Vec<String> =
        build_tables(None, &merged.records).unwrap().iter().map(|t| t.render()).collect();
    assert_eq!(merged_tables, single_tables);

    for p in [&single, &la, &lb] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn header_hash_mismatch_is_rejected_on_resume_and_merge() {
    let plan = test_plan();
    let la = temp("hdr_a");
    let _ = std::fs::remove_file(&la);
    execute(&plan, &opts_for(&la, ShardSpec::solo()), &mut []).unwrap();

    // A different campaign (here: a different seed axis) must not
    // resume from this ledger...
    let mut other = plan.clone();
    other.seeds = vec![0];
    let err = execute(&other, &opts_for(&la, ShardSpec::solo()), &mut []).unwrap_err();
    assert!(err.to_string().contains("different campaign"), "err: {err}");

    // ...must not merge against it...
    let err = merge_ledgers(&[&la], Some(&other)).unwrap_err();
    assert!(err.to_string().contains("different campaign"), "err: {err}");

    // ...and two different campaigns' ledgers must not merge together.
    let lb = temp("hdr_b");
    write_ledger(&lb, Some(&PlanHeader::for_plan(&other)), &[]).unwrap();
    let err = merge_ledgers(&[&la, &lb], None).unwrap_err();
    assert!(err.to_string().contains("different campaigns"), "err: {err}");

    std::fs::remove_file(&la).ok();
    std::fs::remove_file(&lb).ok();
}

#[test]
fn steal_reclaims_expired_claims_but_respects_live_leases() {
    let plan = test_plan();
    let n = plan.n_runs();
    let shard0 = ShardSpec::parse("0/2").unwrap();
    let foreign: Vec<String> = plan
        .cells()
        .iter()
        .map(|c| c.key())
        .filter(|k| !shard0.contains(k))
        .collect();
    assert!(foreign.len() >= 2, "test plan must spread across both shards");
    let dead_key = &foreign[0]; // expired lease -> stealable
    let live_key = &foreign[1]; // live foreign lease -> left alone

    // Shared ledger pre-populated with the header and the two claims.
    let ls = temp("steal");
    let _ = std::fs::remove_file(&ls);
    let mut body = format!("{}\n", PlanHeader::for_plan(&plan).to_json());
    body.push_str(&ClaimRecord::new(dead_key.clone(), "dead-worker", 1, 1).to_json());
    body.push('\n');
    body.push_str(&ClaimRecord::new(live_key.clone(), "other", now_unix(), 3600).to_json());
    body.push('\n');
    std::fs::write(&ls, &body).unwrap();

    let opts = ExecOptions {
        threads: 2,
        ledger: Some(ls.clone()),
        shard: shard0,
        steal: true,
        worker: Some("w0".into()),
        ..Default::default()
    };
    let summary = execute(&plan, &opts, &mut []).unwrap();
    // Everything except the live-leased run completed: own shard, plus
    // all unclaimed foreign keys, plus the dead worker's expired claim.
    assert_eq!(summary.n_skipped, 1, "only the live lease is left alone");
    assert_eq!(summary.records.len(), n - 1);
    let done: Vec<String> = summary.records.iter().map(|r| r.key()).collect();
    assert!(done.contains(dead_key), "expired claim was reclaimed");
    assert!(!done.contains(live_key), "live foreign lease was respected");

    // The thief stamped its own claims into the shared ledger.
    let led = read_dist_ledger(&ls).unwrap();
    assert_eq!(led.claims[dead_key].worker, "w0", "reclaim is announced");
    assert_eq!(led.claims[live_key].worker, "other", "live lease untouched");
    assert_eq!(led.runs.len(), n - 1);

    std::fs::remove_file(&ls).ok();
}

#[test]
fn collector_renews_leases_mid_batch_and_telemetry_counts_it() {
    // lease_s = 0 makes the half-lease renewal threshold 0 seconds, so
    // the collector re-stamps claims for the still-pending runs after
    // every completion — the degenerate setting turns "renew before the
    // lease can expire" into something a fast test can observe.
    let plan = test_plan();
    let n = plan.n_runs();
    let ls = temp("renew");
    let _ = std::fs::remove_file(&ls);
    let opts = ExecOptions {
        threads: 2,
        ledger: Some(ls.clone()),
        worker: Some("w0".into()),
        lease_s: 0,
        telemetry: true,
        ..Default::default()
    };
    let summary = execute(&plan, &opts, &mut []).unwrap();
    assert_eq!(summary.records.len(), n);
    let text = std::fs::read_to_string(&ls).unwrap();
    let n_claims = text.matches("\"kind\":\"claim\"").count();
    assert!(
        n_claims > n,
        "expected the {n} batch-start claims plus mid-batch renewals, got {n_claims}"
    );
    let led = read_dist_ledger(&ls).unwrap();
    assert_eq!(led.runs.len(), n);
    assert_eq!(led.n_torn, 0, "telem lines must parse, not count as torn");
    let renewals: u64 = led
        .telem
        .iter()
        .filter(|t| t.metric == "dist.lease_renewals")
        .filter_map(|t| t.counter)
        .sum();
    assert!(renewals > 0, "renewals surface as a campaign telemetry counter");
    // Campaign-scope lines are keyed by the worker id; per-run lines by
    // the run's coordinate key.
    assert!(led.telem.iter().any(|t| t.scope == "campaign" && t.key == "w0"));
    assert!(led.telem.iter().any(|t| t.scope == "run"));
    std::fs::remove_file(&ls).ok();
}

#[test]
fn overlapping_ledgers_dedup_to_bit_identical_tables() {
    let plan = test_plan();
    let n = plan.n_runs();
    let lfull = temp("ovl_full");
    let la = temp("ovl_a");
    for p in [&lfull, &la] {
        let _ = std::fs::remove_file(p);
    }
    // One worker ran everything; another (redundantly) ran shard 0 —
    // every shard-0 run exists twice across the fleet.
    let full = execute(&plan, &opts_for(&lfull, ShardSpec::solo()), &mut []).unwrap();
    let a = execute(&plan, &opts_for(&la, ShardSpec::parse("0/2").unwrap()), &mut [])
        .unwrap();
    let merged = merge_ledgers(&[&la, &lfull], Some(&plan)).unwrap();
    assert!(merged.complete());
    assert_eq!(merged.n_duplicates, a.records.len(), "overlap deduped by key");
    assert_eq!(merged.records.len(), n);
    let t1: Vec<String> =
        build_tables(None, &full.records).unwrap().iter().map(|t| t.render()).collect();
    let t2: Vec<String> =
        build_tables(None, &merged.records).unwrap().iter().map(|t| t.render()).collect();
    assert_eq!(t1, t2, "duplicates must not change a single byte");

    for p in [&lfull, &la] {
        std::fs::remove_file(p).ok();
    }
}

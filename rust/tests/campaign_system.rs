//! System tests for the declarative campaign layer (ISSUE-4):
//!
//! * paper-table parity — every `nacfl exp` preset produces
//!   bit-identical tables through the unified engine and the retained
//!   legacy `run_cell` path;
//! * manifest execution — a `[campaign]` TOML manifest parses, round-
//!   trips through Display, and executes a mixed analytic + DES
//!   campaign;
//! * ledger resume — a campaign interrupted mid-run (torn trailing
//!   ledger line included) resumes from its JSONL ledger and finishes
//!   bit-identically to an uninterrupted run.

use nacfl::config::ExperimentConfig;
use nacfl::des::Discipline;
use nacfl::exp::{
    execute, run_cell, table_cells, table_for, table_plans, ExecOptions, ExperimentPlan,
    MemorySink, ResultSink, TableSink, Tier,
};
use nacfl::netsim::ScenarioKind;

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("nacfl_{tag}_{}", std::process::id()))
}

#[test]
fn engine_tables_are_bit_identical_to_legacy_for_all_presets() {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..4).collect();
    let tier = Tier::Analytic { k_eps: 80.0 };
    for table in ["table1", "table2", "table3", "table4", "theorem1"] {
        let cells = table_cells(table, &base).unwrap();
        let plans = table_plans(table, &base, tier).unwrap();
        assert_eq!(cells.len(), plans.len());
        for ((label, cfg), (_, plan)) in cells.iter().zip(plans.iter()) {
            let legacy = run_cell(cfg, tier, |_, _, _| {}).unwrap();
            let legacy_render = table_for(label, &legacy).unwrap().render();

            let mut sink = TableSink::new(Some(label.clone()));
            let summary = execute(
                plan,
                &ExecOptions { threads: 4, ledger: None },
                &mut [&mut sink],
            )
            .unwrap();

            // Per-run walls are bit-identical, policy-major seed-minor.
            let mut it = summary.records.iter();
            for cr in &legacy {
                for (si, &wall) in cr.times.iter().enumerate() {
                    let rec = it.next().unwrap();
                    assert_eq!(rec.policy, cr.policy, "{table} {label}");
                    assert_eq!(rec.seed, cfg.seeds[si]);
                    assert_eq!(
                        rec.wall.to_bits(),
                        wall.to_bits(),
                        "{table} {label}: {} seed {}",
                        rec.policy,
                        rec.seed
                    );
                    assert_eq!(rec.rounds, cr.rounds[si]);
                }
            }
            assert!(it.next().is_none(), "{table} {label}: extra engine records");

            // And the rendered paper table is byte-identical.
            assert_eq!(sink.tables.len(), 1, "{table} {label}");
            assert_eq!(sink.tables[0].render(), legacy_render, "{table} {label}");
        }
    }
}

#[test]
fn manifest_executes_a_mixed_analytic_plus_des_campaign() {
    let text = r#"
# Mixed campaign: sync cells take the analytic closed form, semi-sync
# cells run through the DES engine — one plan, one engine.
[campaign]
name = "mixed smoke"
scenarios = ["homog:2"]
policies = ["fixed:2", "nacfl:1"]
tiers = ["sim:60"]
disciplines = ["sync", "semi-sync:7"]
seeds = 2
"#;
    let plan = ExperimentPlan::parse_manifest(text).unwrap();
    assert_eq!(plan.n_runs(), 8, "2 disciplines x 2 policies x 2 seeds");

    // Display round-trips to an equivalent plan.
    let back = ExperimentPlan::parse_manifest(&plan.to_string()).unwrap();
    assert_eq!(back.cells(), plan.cells());

    let mut mem = MemorySink::default();
    let mut sinks: Vec<&mut dyn ResultSink> = vec![&mut mem];
    let summary = execute(&plan, &ExecOptions::default(), &mut sinks).unwrap();
    assert_eq!(summary.records.len(), plan.n_runs());
    assert_eq!(mem.records.len(), plan.n_runs());

    // The sync half is the analytic tier exactly: compare against the
    // legacy run_cell on the equivalent config.
    let mut cfg = plan.base.clone();
    cfg.scenario = ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 };
    cfg.policies = plan.policies.clone();
    cfg.seeds = plan.seeds.clone();
    let legacy = run_cell(&cfg, Tier::Analytic { k_eps: 60.0 }, |_, _, _| {}).unwrap();
    for cr in &legacy {
        for (si, &wall) in cr.times.iter().enumerate() {
            let rec = summary
                .records
                .iter()
                .find(|r| {
                    r.discipline == "sync" && r.policy == cr.policy && r.seed == cfg.seeds[si]
                })
                .unwrap();
            assert_eq!(rec.wall.to_bits(), wall.to_bits());
        }
    }
    // The semi-sync half really went through the DES engine.
    let late: usize = summary
        .records
        .iter()
        .filter(|r| r.discipline == "semi-sync:7")
        .map(|r| r.late)
        .sum();
    assert!(late > 0, "semi-sync cells must abandon some transfers");
}

#[test]
fn campaign_resumes_bit_identically_from_a_torn_ledger() {
    let ledger_path = temp_path("resume_ledger");
    let ledger = ledger_path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&ledger);

    let mut base = ExperimentConfig::paper();
    base.seeds = (0..3).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    let plan = ExperimentPlan::builder("resume demo")
        .base(base)
        .scenarios(vec![ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 }])
        .tiers(vec![Tier::Analytic { k_eps: 60.0 }])
        .disciplines(vec![Discipline::Sync, Discipline::SemiSync { k: 7 }])
        .build()
        .unwrap();
    let n = plan.n_runs();
    assert_eq!(n, 12);

    // Uninterrupted reference run, streaming the ledger.
    let mut sinks: Vec<&mut dyn ResultSink> = Vec::new();
    let full = execute(
        &plan,
        &ExecOptions { threads: 2, ledger: Some(ledger.clone()) },
        &mut sinks,
    )
    .unwrap();
    assert_eq!(full.n_executed, n);
    assert_eq!(full.n_cached, 0);

    // Simulate a mid-run kill: keep 5 complete ledger lines plus one
    // torn half-line (the write that was interrupted).
    let text = std::fs::read_to_string(&ledger).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), n, "one ledger line per run");
    let mut torn = lines[..5].join("\n");
    torn.push('\n');
    torn.push_str(&lines[5][..lines[5].len() / 2]);
    std::fs::write(&ledger, &torn).unwrap();

    // Resume: 5 runs come from the ledger, the rest re-execute, and the
    // final records are bit-identical to the uninterrupted run.
    let mut sinks: Vec<&mut dyn ResultSink> = Vec::new();
    let resumed = execute(
        &plan,
        &ExecOptions { threads: 2, ledger: Some(ledger.clone()) },
        &mut sinks,
    )
    .unwrap();
    assert_eq!(resumed.n_cached, 5);
    assert_eq!(resumed.n_executed, n - 5);
    assert_eq!(resumed.records.len(), n);
    for (a, b) in full.records.iter().zip(resumed.records.iter()) {
        assert_eq!(a.key(), b.key(), "plan order must be stable");
        assert_eq!(
            a.wall.to_bits(),
            b.wall.to_bits(),
            "resumed wall must be bit-identical for {}",
            a.key()
        );
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.converged, b.converged);
    }

    // A third invocation is fully cached (skip-completed on rerun).
    let mut sinks: Vec<&mut dyn ResultSink> = Vec::new();
    let third = execute(
        &plan,
        &ExecOptions { threads: 1, ledger: Some(ledger.clone()) },
        &mut sinks,
    )
    .unwrap();
    assert_eq!(third.n_cached, n);
    assert_eq!(third.n_executed, 0);
    for (a, b) in full.records.iter().zip(third.records.iter()) {
        assert_eq!(a.wall.to_bits(), b.wall.to_bits());
    }

    // Editing the base config invalidates every cached record (the
    // fingerprint no longer matches), so nothing stale is served.
    let mut edited = plan.clone();
    edited.base.c_q *= 2.0;
    let mut sinks: Vec<&mut dyn ResultSink> = Vec::new();
    let fourth = execute(
        &edited,
        &ExecOptions { threads: 1, ledger: Some(ledger.clone()) },
        &mut sinks,
    )
    .unwrap();
    assert_eq!(fourth.n_cached, 0, "changed base config must re-execute");
    assert_eq!(fourth.n_executed, n);

    std::fs::remove_file(&ledger).ok();
}

#[test]
fn compressor_axis_fans_out_within_one_campaign() {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..2).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    let plan = ExperimentPlan::builder("compressors")
        .base(base)
        .compressors(vec!["quant:inf", "topk:0.05", "errbound:1.5625"])
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .build()
        .unwrap();
    let mut sinks: Vec<&mut dyn ResultSink> = Vec::new();
    let summary = execute(&plan, &ExecOptions { threads: 2, ledger: None }, &mut sinks).unwrap();
    assert_eq!(summary.records.len(), 3 * 2 * 2);
    // Each compressor family prices differently, so the same (policy,
    // seed) cell must not produce identical walls across all families.
    let wall_of = |comp: &str| {
        summary
            .records
            .iter()
            .find(|r| r.compressor == comp && r.policy == "nacfl:1" && r.seed == 0)
            .unwrap()
            .wall
    };
    let (a, b, c) = (wall_of("quant:inf"), wall_of("topk:0.05"), wall_of("errbound:1.5625"));
    assert!(
        a != b || b != c,
        "compressor axis had no effect: {a:.3e} {b:.3e} {c:.3e}"
    );
}

//! System tests for the declarative campaign layer (ISSUE-4/5):
//!
//! * paper-table parity — every `nacfl exp` preset produces tables
//!   byte-identical to the *pinned reference*: an inline copy of the
//!   retired `run_cell` sequential loop (per policy, per seed, one
//!   `sim::simulate` over the paired congestion process).  This froze
//!   the legacy float path when the legacy drivers were deleted;
//! * manifest execution — a `[campaign]` TOML manifest parses, round-
//!   trips through Display, and executes a mixed analytic + DES
//!   campaign;
//! * ledger resume — a campaign interrupted mid-run (torn trailing
//!   ledger line included) resumes from its JSONL ledger and finishes
//!   bit-identically to an uninterrupted run; a base-config edit is a
//!   different campaign (plan-hash header) and is refused.

use nacfl::config::ExperimentConfig;
use nacfl::des::Discipline;
use nacfl::exp::{
    execute, table_cells, table_for, table_plans, CellResult, ExecOptions, ExperimentPlan,
    MemorySink, ResultSink, TableSink, Tier,
};
use nacfl::netsim::ScenarioKind;
use nacfl::policy::{PolicyEnv, PolicySpec};
use nacfl::sim::simulate;

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("nacfl_{tag}_{}", std::process::id()))
}

/// The pinned reference: the legacy `run_cell` analytic loop, inlined.
/// Per policy, per seed — policy-major, seed-minor — one analytic
/// simulation on the seed-paired congestion process.  Every float here
/// is the exact path the paper tables shipped with.
fn reference_cell(cfg: &ExperimentConfig, k_eps: f64) -> Vec<CellResult> {
    let ctx = cfg.policy_ctx();
    cfg.policies
        .iter()
        .map(|spec| {
            let mut times = Vec::with_capacity(cfg.seeds.len());
            let mut rounds = Vec::with_capacity(cfg.seeds.len());
            for &seed in &cfg.seeds {
                let env = PolicyEnv::for_cell(&ctx, cfg.scenario, cfg.m, seed);
                let mut policy = PolicySpec::parse(spec).unwrap().build(&env).unwrap();
                let mut process = cfg.congestion_process(seed).unwrap();
                let r = simulate(&ctx, policy.as_mut(), &mut process, k_eps, 10_000_000);
                times.push(r.wall);
                rounds.push(r.rounds);
            }
            CellResult {
                policy: spec.clone(),
                times,
                rounds,
                traces: Vec::new(),
                unconverged: 0,
            }
        })
        .collect()
}

#[test]
fn engine_tables_are_bit_identical_to_the_pinned_reference_for_all_presets() {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..4).collect();
    let k_eps = 80.0;
    let tier = Tier::Analytic { k_eps };
    for table in ["table1", "table2", "table3", "table4", "theorem1"] {
        let cells = table_cells(table, &base).unwrap();
        let plans = table_plans(table, &base, tier).unwrap();
        assert_eq!(cells.len(), plans.len());
        for ((label, cfg), (_, plan)) in cells.iter().zip(plans.iter()) {
            let reference = reference_cell(cfg, k_eps);
            let reference_render = table_for(label, &reference).unwrap().render();

            let mut sink = TableSink::new(Some(label.clone()));
            let summary =
                execute(plan, &ExecOptions::with_threads(4), &mut [&mut sink]).unwrap();

            // Per-run walls are bit-identical, policy-major seed-minor.
            let mut it = summary.records.iter();
            for cr in &reference {
                for (si, &wall) in cr.times.iter().enumerate() {
                    let rec = it.next().unwrap();
                    assert_eq!(rec.policy, cr.policy, "{table} {label}");
                    assert_eq!(rec.seed, cfg.seeds[si]);
                    assert_eq!(
                        rec.wall.to_bits(),
                        wall.to_bits(),
                        "{table} {label}: {} seed {}",
                        rec.policy,
                        rec.seed
                    );
                    assert_eq!(rec.rounds, cr.rounds[si]);
                }
            }
            assert!(it.next().is_none(), "{table} {label}: extra engine records");

            // And the rendered paper table is byte-identical.
            assert_eq!(sink.tables.len(), 1, "{table} {label}");
            assert_eq!(sink.tables[0].render(), reference_render, "{table} {label}");
        }
    }
}

#[test]
fn manifest_executes_a_mixed_analytic_plus_des_campaign() {
    let text = r#"
# Mixed campaign: sync cells take the analytic closed form, semi-sync
# cells run through the DES engine — one plan, one engine.
[campaign]
name = "mixed smoke"
scenarios = ["homog:2"]
policies = ["fixed:2", "nacfl:1"]
tiers = ["sim:60"]
disciplines = ["sync", "semi-sync:7"]
seeds = 2
"#;
    let plan = ExperimentPlan::parse_manifest(text).unwrap();
    assert_eq!(plan.n_runs(), 8, "2 disciplines x 2 policies x 2 seeds");

    // Display round-trips to an equivalent plan (now self-contained:
    // the base config sections ride along).
    let back = ExperimentPlan::parse_manifest(&plan.to_string()).unwrap();
    assert_eq!(back.cells(), plan.cells());
    assert_eq!(back.plan_hash(), plan.plan_hash());

    let mut mem = MemorySink::default();
    let mut sinks: Vec<&mut dyn ResultSink> = vec![&mut mem];
    let summary = execute(&plan, &ExecOptions::default(), &mut sinks).unwrap();
    assert_eq!(summary.records.len(), plan.n_runs());
    assert_eq!(mem.records.len(), plan.n_runs());

    // The sync half is the analytic tier exactly: compare against the
    // pinned reference on the equivalent config.
    let mut cfg = plan.base.clone();
    cfg.scenario = ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 };
    cfg.policies = plan.policies.clone();
    cfg.seeds = plan.seeds.clone();
    let reference = reference_cell(&cfg, 60.0);
    for cr in &reference {
        for (si, &wall) in cr.times.iter().enumerate() {
            let rec = summary
                .records
                .iter()
                .find(|r| {
                    r.discipline == "sync" && r.policy == cr.policy && r.seed == cfg.seeds[si]
                })
                .unwrap();
            assert_eq!(rec.wall.to_bits(), wall.to_bits());
        }
    }
    // The semi-sync half really went through the DES engine.
    let late: usize = summary
        .records
        .iter()
        .filter(|r| r.discipline == "semi-sync:7")
        .map(|r| r.late)
        .sum();
    assert!(late > 0, "semi-sync cells must abandon some transfers");
}

#[test]
fn campaign_resumes_bit_identically_from_a_torn_ledger() {
    let ledger_path = temp_path("resume_ledger");
    let ledger = ledger_path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&ledger);

    let mut base = ExperimentConfig::paper();
    base.seeds = (0..3).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    let plan = ExperimentPlan::builder("resume demo")
        .base(base)
        .scenarios(vec![ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 }])
        .tiers(vec![Tier::Analytic { k_eps: 60.0 }])
        .disciplines(vec![Discipline::Sync, Discipline::SemiSync { k: 7 }])
        .build()
        .unwrap();
    let n = plan.n_runs();
    assert_eq!(n, 12);

    let opts = |threads: usize| ExecOptions {
        threads,
        ledger: Some(ledger.clone()),
        ..Default::default()
    };

    // Uninterrupted reference run, streaming the ledger.
    let full = execute(&plan, &opts(2), &mut []).unwrap();
    assert_eq!(full.n_executed, n);
    assert_eq!(full.n_cached, 0);

    // Simulate a mid-run kill: keep the plan header + 5 complete run
    // lines plus one torn half-line (the write that was interrupted).
    let text = std::fs::read_to_string(&ledger).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), n + 1, "plan header + one ledger line per run");
    assert!(lines[0].contains("\"kind\":\"plan\""), "first line is the header");
    let mut torn = lines[..6].join("\n");
    torn.push('\n');
    torn.push_str(&lines[6][..lines[6].len() / 2]);
    std::fs::write(&ledger, &torn).unwrap();

    // Resume: 5 runs come from the ledger, the rest re-execute, and the
    // final records are bit-identical to the uninterrupted run.
    let resumed = execute(&plan, &opts(2), &mut []).unwrap();
    assert_eq!(resumed.n_cached, 5);
    assert_eq!(resumed.n_executed, n - 5);
    assert_eq!(resumed.records.len(), n);
    for (a, b) in full.records.iter().zip(resumed.records.iter()) {
        assert_eq!(a.key(), b.key(), "plan order must be stable");
        assert_eq!(
            a.wall.to_bits(),
            b.wall.to_bits(),
            "resumed wall must be bit-identical for {}",
            a.key()
        );
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.converged, b.converged);
    }

    // A third invocation is fully cached (skip-completed on rerun).
    let third = execute(&plan, &opts(1), &mut []).unwrap();
    assert_eq!(third.n_cached, n);
    assert_eq!(third.n_executed, 0);
    for (a, b) in full.records.iter().zip(third.records.iter()) {
        assert_eq!(a.wall.to_bits(), b.wall.to_bits());
    }

    // Editing the base config changes the plan hash: the ledger header
    // no longer matches, so resuming is refused instead of silently
    // mixing campaigns (use --fresh or a new ledger path).
    let mut edited = plan.clone();
    edited.base.c_q *= 2.0;
    let err = execute(&edited, &opts(1), &mut []).unwrap_err();
    assert!(
        err.to_string().contains("different campaign"),
        "edited base must be refused: {err}"
    );
    // On a fresh ledger the edited campaign executes from scratch.
    let fresh = temp_path("resume_fresh");
    let fresh_opts = ExecOptions {
        threads: 1,
        ledger: Some(fresh.to_str().unwrap().to_string()),
        ..Default::default()
    };
    let fourth = execute(&edited, &fresh_opts, &mut []).unwrap();
    assert_eq!(fourth.n_cached, 0, "changed base config must re-execute");
    assert_eq!(fourth.n_executed, n);

    std::fs::remove_file(&ledger).ok();
    std::fs::remove_file(&fresh).ok();
}

#[test]
fn compressor_axis_fans_out_within_one_campaign() {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..2).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    let plan = ExperimentPlan::builder("compressors")
        .base(base)
        .compressors(vec!["quant:inf", "topk:0.05", "errbound:1.5625"])
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .build()
        .unwrap();
    let mut sinks: Vec<&mut dyn ResultSink> = Vec::new();
    let summary = execute(&plan, &ExecOptions::with_threads(2), &mut sinks).unwrap();
    assert_eq!(summary.records.len(), 3 * 2 * 2);
    // Each compressor family prices differently, so the same (policy,
    // seed) cell must not produce identical walls across all families.
    let wall_of = |comp: &str| {
        summary
            .records
            .iter()
            .find(|r| r.compressor == comp && r.policy == "nacfl:1" && r.seed == 0)
            .unwrap()
            .wall
    };
    let (a, b, c) = (wall_of("quant:inf"), wall_of("topk:0.05"), wall_of("errbound:1.5625"));
    assert!(
        a != b || b != c,
        "compressor axis had no effect: {a:.3e} {b:.3e} {c:.3e}"
    );
}

//! System tests for the fault axis (ISSUE-8):
//!
//! * a faulty campaign (`loss+deadline+crash`) double-runs to
//!   **byte-identical** ledgers across sync / semi-sync / async
//!   disciplines — fault draws are coordinate-pure, not schedule-bound;
//! * a plan with no fault axis and a plan with an explicit
//!   `faults = ["none"]` axis share a plan hash and produce
//!   byte-identical, fault-field-free ledgers (pre-fault byte shape);
//! * tier-weighted sharding splits every cost class ±1 across workers
//!   and the fleet's ledgers merge bit-identically to a solo run;
//! * ledger crash recovery holds under seeded fuzz — torn lines,
//!   duplicated records, interleaved ghost claims: readers never lose a
//!   completed record, resume re-executes exactly the lost runs, and
//!   compaction is idempotent and lossless;
//! * under `deadline:<s>:quorum<frac>` the per-run delay decomposition
//!   still sums to the wall clock — time burned by sub-quorum rounds is
//!   charged to `wait_s`, never to phantom upload time.

use std::collections::{HashMap, HashSet};

use nacfl::config::ExperimentConfig;
use nacfl::des::Discipline;
use nacfl::exp::{
    build_tables, compact_ledger, execute, merge_ledgers, read_dist_ledger, ClaimRecord,
    ExecOptions, ExperimentPlan, ShardSpec, Tier,
};
use nacfl::util::rng::Rng;

fn temp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nacfl_fault_sys_{tag}_{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn small_base() -> ExperimentConfig {
    let mut base = ExperimentConfig::paper();
    base.seeds = (0..2).collect();
    base.policies = vec!["fixed:2".into(), "nacfl:1".into()];
    base
}

fn opts_for(ledger: &str, threads: usize) -> ExecOptions {
    ExecOptions {
        threads,
        ledger: Some(ledger.to_string()),
        ..Default::default()
    }
}

/// Uploads on the paper scenarios take O(1e6) simulated seconds, so the
/// deadline sits at a few uploads' worth and crashes arrive every few
/// tens of rounds — all three fault channels fire without starving the
/// rounds outright.
const FAULTS: &str = "loss:0.15:retry2+deadline:4000000:quorum0.5+crash:40000000x4000000";

#[test]
fn faulty_campaign_double_runs_byte_identically_across_disciplines() {
    let plan = ExperimentPlan::builder("fault determinism")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .disciplines(vec![
            Discipline::Sync,
            Discipline::SemiSync { k: 7 },
            Discipline::Async { staleness_exp: 0.5 },
        ])
        .faults([FAULTS])
        .build()
        .unwrap();

    let la = temp("det_a");
    let lb = temp("det_b");
    for p in [&la, &lb] {
        let _ = std::fs::remove_file(p);
    }
    // Single-threaded so the ledger's line order is execution order —
    // the byte comparison then pins the records *and* their layout.
    let a = execute(&plan, &opts_for(&la, 1), &mut []).unwrap();
    execute(&plan, &opts_for(&lb, 1), &mut []).unwrap();
    let bytes_a = std::fs::read_to_string(&la).unwrap();
    let bytes_b = std::fs::read_to_string(&lb).unwrap();
    assert_eq!(bytes_a, bytes_b, "double run must be byte-identical");

    // The fault coordinate and its health fields ride on every record.
    assert_eq!(a.records.len(), plan.n_runs());
    assert!(bytes_a.contains("\"faults\":\"loss:0.15:retry2"));
    assert!(bytes_a.contains("\"retrans_s\":"));
    assert!(bytes_a.contains("\"quorum_frac\":"));
    assert!(
        a.records.iter().any(|r| r.retrans_s > 0.0),
        "15% loss must charge retransmission time somewhere"
    );
    for r in &a.records {
        assert!(r.retrans_s.is_finite() && r.retrans_s >= 0.0, "{}", r.key());
        assert!(
            r.quorum_frac.is_finite() && (0.0..=1.0).contains(&r.quorum_frac),
            "{}: quorum_frac {}",
            r.key(),
            r.quorum_frac
        );
    }

    // With telemetry on, retransmissions surface as a counter.
    let lt = temp("det_telem");
    let _ = std::fs::remove_file(&lt);
    let opts = ExecOptions {
        telemetry: true,
        ..opts_for(&lt, 2)
    };
    execute(&plan, &opts, &mut []).unwrap();
    let telem = std::fs::read_to_string(&lt).unwrap();
    assert!(telem.contains("net.retries"), "retries must be counted");

    for p in [&la, &lb, &lt] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn axis_free_and_explicit_none_plans_share_bytes_and_hash() {
    let plain = ExperimentPlan::builder("fault parity")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .build()
        .unwrap();
    let explicit = ExperimentPlan::builder("fault parity")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .faults(["none"])
        .build()
        .unwrap();
    assert_eq!(
        plain.plan_hash(),
        explicit.plan_hash(),
        "a trivial fault axis must not re-key the campaign"
    );

    let la = temp("none_a");
    let lb = temp("none_b");
    for p in [&la, &lb] {
        let _ = std::fs::remove_file(p);
    }
    execute(&plain, &opts_for(&la, 1), &mut []).unwrap();
    execute(&explicit, &opts_for(&lb, 1), &mut []).unwrap();
    let bytes_a = std::fs::read_to_string(&la).unwrap();
    let bytes_b = std::fs::read_to_string(&lb).unwrap();
    assert_eq!(bytes_a, bytes_b);
    // Fault-free ledgers keep the pre-fault byte shape: no fault fields
    // on any line, keys without a faults suffix.
    assert!(!bytes_a.contains("\"faults\""));
    assert!(!bytes_a.contains("retrans_s"));

    for p in [&la, &lb] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn weighted_shards_balance_cost_classes_and_merge_bit_identically() {
    // A mixed fault axis puts half the cells on the analytic closed
    // form and half on the DES engine — exactly the split the
    // tier-weighted sharder must balance (a count-only split could hand
    // one worker all the slow DES cells).
    let plan = ExperimentPlan::builder("fault shards")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .faults(["none", "loss:0.2:retry2"])
        .build()
        .unwrap();
    let n = plan.n_runs();
    assert_eq!(n, 8);

    let lfull = temp("shard_full");
    let la = temp("shard_w0");
    let lb = temp("shard_w1");
    for p in [&lfull, &la, &lb] {
        let _ = std::fs::remove_file(p);
    }
    let full = execute(&plan, &opts_for(&lfull, 2), &mut []).unwrap();
    let mk = |ledger: &str, spec: &str| ExecOptions {
        shard: ShardSpec::parse(spec).unwrap(),
        ..opts_for(ledger, 2)
    };
    let a = execute(&plan, &mk(&la, "0/2"), &mut []).unwrap();
    let b = execute(&plan, &mk(&lb, "1/2"), &mut []).unwrap();
    assert_eq!(a.records.len() + b.records.len(), n, "disjoint and exhaustive");
    // Each worker gets its fair share of *each* cost class, ±1.
    for shard in [&a, &b] {
        let des = shard.records.iter().filter(|r| r.faults != "none").count();
        let analytic = shard.records.len() - des;
        assert_eq!(des, 2, "DES cells split evenly");
        assert_eq!(analytic, 2, "analytic cells split evenly");
    }

    let merged = merge_ledgers(&[&la, &lb], Some(&plan)).unwrap();
    assert!(merged.complete(), "missing: {:?}", merged.missing);
    for (x, y) in full.records.iter().zip(merged.records.iter()) {
        assert_eq!(x.key(), y.key(), "merge must return plan order");
        assert_eq!(x.wall.to_bits(), y.wall.to_bits(), "{}", x.key());
        assert_eq!(x.retrans_s.to_bits(), y.retrans_s.to_bits(), "{}", x.key());
    }
    let t1: Vec<String> =
        build_tables(None, &full.records).unwrap().iter().map(|t| t.render()).collect();
    let t2: Vec<String> =
        build_tables(None, &merged.records).unwrap().iter().map(|t| t.render()).collect();
    assert_eq!(t1, t2, "fleet tables == single-machine tables");

    for p in [&lfull, &la, &lb] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn deadline_quorum_decomposition_sums_to_wall_across_disciplines() {
    // Sub-quorum rounds burn wall-clock time with no aggregation; the
    // engine charges that time to `wait_s` (never phantom upload for
    // abandoned in-flight transfers), so the decomposition must sum to
    // the wall on every discipline's path.  Heavy loss plus a tight
    // deadline with a quorum makes such rounds common.
    let plan = ExperimentPlan::builder("quorum decomposition")
        .base(small_base())
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .disciplines(vec![
            Discipline::Sync,
            Discipline::SemiSync { k: 7 },
            Discipline::Async { staleness_exp: 0.5 },
        ])
        .faults(["loss:0.3+deadline:4000000:quorum0.5"])
        .build()
        .unwrap();
    let summary = execute(
        &plan,
        &ExecOptions { threads: 2, ..Default::default() },
        &mut [],
    )
    .unwrap();
    assert_eq!(summary.records.len(), plan.n_runs());
    for r in &summary.records {
        let sum = r.upload_s + r.compute_s + r.wait_s;
        assert!(
            (sum - r.wall).abs() <= 1e-9 * r.wall.abs().max(1.0),
            "{}: upload {} + compute {} + wait {} = {} != wall {}",
            r.key(),
            r.upload_s,
            r.compute_s,
            r.wait_s,
            sum,
            r.wall
        );
        assert!(r.quorum_frac.is_finite() && r.quorum_frac <= 1.0, "{}", r.key());
        // Sync never closes a round early, so burned deadline time must
        // surface as non-negative wait — charged busy time can never
        // exceed the wall clock.  (Early-close disciplines legitimately
        // overlap rounds, so wait_s may go negative there.)
        if r.discipline == "sync" {
            assert!(
                r.wait_s >= 0.0,
                "{}: burned deadline time must land in wait_s, got {}",
                r.key(),
                r.wait_s
            );
            assert!(
                r.upload_s + r.compute_s <= r.wall * (1.0 + 1e-12),
                "{}: phantom upload charge: {} + {} > wall {}",
                r.key(),
                r.upload_s,
                r.compute_s,
                r.wall
            );
        }
    }
    // The deadline channel actually bit somewhere in the grid.
    assert!(
        summary.records.iter().any(|r| r.quorum_frac < 1.0),
        "deadline+quorum must shrink some aggregate"
    );
}

#[test]
fn ledger_recovery_survives_fuzzed_truncation_duplication_and_claims() {
    let plan = ExperimentPlan::builder("fault fuzz")
        .base({
            let mut b = small_base();
            b.seeds = (0..3).collect();
            b
        })
        .tiers(vec![Tier::Analytic { k_eps: 50.0 }])
        .build()
        .unwrap();
    let n = plan.n_runs();
    let cells = plan.cells();

    let lref = temp("fuzz_ref");
    let _ = std::fs::remove_file(&lref);
    let full = execute(&plan, &opts_for(&lref, 1), &mut []).unwrap();
    assert_eq!(full.records.len(), n);
    let by_key: HashMap<String, &nacfl::exp::RunRecord> =
        full.records.iter().map(|r| (r.key(), r)).collect();
    let reference = std::fs::read_to_string(&lref).unwrap();
    let ref_lines: Vec<&str> = reference.lines().collect();
    assert_eq!(ref_lines.len(), n + 1, "header + one record per run");

    let lf = temp("fuzz_work");
    for fuzz_seed in 0..8u64 {
        let mut rng = Rng::new(0xFA01).derive("fuzz", fuzz_seed);
        let mut lines: Vec<String> = ref_lines.iter().map(|s| s.to_string()).collect();

        // Crash mid-write: one run line is torn at a random byte.
        let ti = 1 + (rng.next_u64() as usize) % n;
        let cut = 1 + (rng.next_u64() as usize) % (lines[ti].len() - 1);
        lines[ti].truncate(cut);
        // Racing workers: a surviving run line lands twice.
        let di = 1 + (rng.next_u64() as usize) % n;
        if di != ti {
            lines.push(lines[di].clone());
        }
        // A dead worker's expired claim, interleaved anywhere after the
        // header.
        let key = cells[(rng.next_u64() as usize) % cells.len()].key();
        let pos = 1 + (rng.next_u64() as usize) % lines.len();
        lines.insert(pos, ClaimRecord::new(key, "ghost", 1, 1).to_json());
        // And a torn tail from the final crash.
        lines.push("{\"kind\":\"telem\",\"scope\":\"run".into());
        std::fs::write(&lf, lines.join("\n") + "\n").unwrap();

        // Readers drop exactly the garbage; every surviving record is
        // bit-identical to the reference.
        let led = read_dist_ledger(&lf).unwrap();
        assert!(led.n_torn >= 2, "seed {fuzz_seed}: torn line + tail");
        let survivors: HashSet<String> = led.runs.iter().map(|r| r.key()).collect();
        for r in &led.runs {
            let want = by_key[&r.key()];
            assert_eq!(r.wall.to_bits(), want.wall.to_bits(), "seed {fuzz_seed}");
            assert_eq!(r.to_json(), want.to_json(), "seed {fuzz_seed}");
        }

        // Resume executes exactly the lost runs (the ghost claim never
        // blocks — only `--steal` consults claims).
        let resumed = execute(&plan, &opts_for(&lf, 2), &mut []).unwrap();
        assert_eq!(resumed.n_cached, survivors.len(), "seed {fuzz_seed}");
        assert_eq!(resumed.n_executed, n - survivors.len(), "seed {fuzz_seed}");

        // Compaction drops the claim and the duplicates, keeps all n
        // runs, and is idempotent.
        compact_ledger(&lf).unwrap();
        let once = std::fs::read_to_string(&lf).unwrap();
        let second = compact_ledger(&lf).unwrap();
        assert_eq!(once, std::fs::read_to_string(&lf).unwrap(), "seed {fuzz_seed}");
        assert_eq!(second.dropped, 0, "seed {fuzz_seed}: already compact");
        let led = read_dist_ledger(&lf).unwrap();
        assert_eq!(led.runs.len(), n, "seed {fuzz_seed}: no completed run lost");
        assert!(led.claims.is_empty(), "seed {fuzz_seed}: claims superseded");
        assert_eq!(led.n_torn, 0, "seed {fuzz_seed}");

        let merged = merge_ledgers(&[&lf], Some(&plan)).unwrap();
        assert!(merged.complete(), "seed {fuzz_seed}");
        for (x, y) in full.records.iter().zip(merged.records.iter()) {
            assert_eq!(x.wall.to_bits(), y.wall.to_bits(), "seed {fuzz_seed}: {}", x.key());
        }
    }

    std::fs::remove_file(&lref).ok();
    std::fs::remove_file(&lf).ok();
}

//! Type-check shim for the `xla` (xla-rs) crate.
//!
//! Mirrors exactly the API surface `nacfl`'s feature-gated PJRT modules
//! consume (`runtime::pjrt` + `runtime::literal`), so `cargo check
//! --features xla` keeps those modules honest without vendoring the
//! real crate.  Every operation returns [`Error`] at runtime; swap this
//! path dependency for the actual xla-rs to execute (see
//! `xla-shim/Cargo.toml`).

use std::fmt;

const SHIM_MSG: &str = "the in-tree `xla` crate is a type-check shim; vendor the real xla-rs \
                        (see rust/xla-shim/Cargo.toml) to execute the PJRT runtime";

/// The shim's only error: "this is a shim".
#[derive(Debug, Clone)]
pub struct Error(&'static str);

impl Default for Error {
    fn default() -> Self {
        Error(SHIM_MSG)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element dtypes used by the nacfl literal helpers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host-side tensor value (shim: carries nothing).
#[derive(Debug, Default)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::default())
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal(())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::default())
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::default())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::default())
    }
}

/// Parsed HLO module proto.
#[derive(Debug, Default)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::default())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Default)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle returned by an execution.
#[derive(Debug, Default)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::default())
    }
}

/// A PJRT client (shim: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::default())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::default())
    }
}

/// A compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_the_shim() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("shim"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::scalar(1.0f32).to_vec::<f32>().is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}

//! Regenerates paper Table IV: partially correlated BTD
//! (sigma_inf^2 = 4, Sigma_ij = 1/2) — positive but imperfect
//! correlation across clients and time.

#[path = "common.rs"]
mod common;

const PAPER: &str = "\
Table IV (units of 1e7 s), policies [1bit 2bit 3bit FixedErr NAC-FL]:
  Mean 13.6 8.33 9.51 4.22 3.83 | 90th 15.9 10.5 13.9 6.24 5.46 | 10th 9.51 5.47 5.80 2.64 2.02 | Gain 307% 129% 159% 10% -
Reproduction target: NAC-FL strictly best on every row; ~10% gain over Fixed-Error.";

fn main() {
    common::run_table("table4", PAPER);
}

//! Regenerates the paper's Fig. 3 sample-path panels (loss & accuracy vs
//! wall clock for homog sigma^2=2, heterog, and perf sigma_inf^2=4).
//!
//! Default: analytic-tier traces (progress proxy) for all five policies,
//! written to results/bench_fig3_*.csv — fast enough for `cargo bench`.
//! The full ML-tier panels (true loss/accuracy through the AOT engine)
//! are produced by `nacfl exp fig3 --out results` and recorded in
//! EXPERIMENTS.md.

use nacfl::config::ExperimentConfig;
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::sim::simulate_traced;
use nacfl::util::rng::Rng;

fn main() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    std::fs::create_dir_all("results").unwrap();
    let panels = [
        ("homog_s2_2", ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 }),
        ("heterog", ScenarioKind::HeterogeneousIndependent),
        ("perf_si2_4", ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: 4.0 }),
    ];
    for (panel, kind) in panels {
        println!("== Fig. 3 panel {panel} ==");
        for spec in nacfl::policy::paper_roster() {
            let sc = Scenario::new(kind, cfg.m);
            let mut p = sc.process(Rng::new(0).derive("net", 0)).unwrap();
            let mut pol = parse_policy(&spec).unwrap();
            let (res, trace) = simulate_traced(&ctx, pol.as_mut(), &mut p, 300.0, 10_000_000);
            let path = format!("results/bench_fig3_{panel}_{}.csv", spec.replace(':', "_"));
            trace.write_csv(&path).unwrap();
            println!(
                "  {spec:<12} finished at wall {:.4e} ({} rounds, mean bits {:.2}) -> {path}",
                res.wall, res.rounds, res.mean_bits
            );
        }
        println!();
    }
    println!(
        "shape check: in the correlated panel NAC-FL's finish time should lead \
         Fixed-Error's; in the independent panels they overlap (paper Fig. 3)."
    );
}

//! Regenerates the paper's Fig. 2 illustration: round duration as a
//! (convex, decreasing) function of the compression parameter q for a
//! fixed network state — the geometry behind Assumption 3.
//!
//! We plot d(tau, b(q), c) against r = h(q) = sqrt(q+1) on the
//! achievable grid and verify decreasing monotonicity plus midpoint
//! convexity along the achievable frontier.

use nacfl::config::ExperimentConfig;
use nacfl::policy::{uniform_choices, RoundsModel};

fn main() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let c = vec![1.0; cfg.m];
    println!(
        "{:>4} {:>12} {:>12} {:>16}   (Fig. 2: duration decreasing + convex in r = h(q))",
        "b", "q(b)", "r = h(q)", "duration d"
    );
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for b in 1..=16u8 {
        let q = ctx.q_of_level(b);
        let r = RoundsModel::h_of_q(q);
        let d = ctx.duration(&uniform_choices(b, cfg.m), &c);
        println!("{:>4} {:>12.4} {:>12.4} {:>16.4e}", b, q, r, d);
        pts.push((r, d));
    }
    // Duration decreases in r (more compression error <=> shorter rounds).
    for w in pts.windows(2) {
        assert!(
            w[1].1 >= w[0].1,
            "d must decrease as r increases (b grows -> r shrinks, d grows)"
        );
    }
    // Midpoint convexity along the achievable frontier (interpolating in r).
    let interp = |r: f64| -> f64 {
        // piecewise-linear interpolation of d over the (sorted-in-r) grid
        let mut sorted = pts.clone();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in sorted.windows(2) {
            if r >= w[0].0 && r <= w[1].0 {
                let f = (r - w[0].0) / (w[1].0 - w[0].0);
                return w[0].1 * (1.0 - f) + w[1].1 * f;
            }
        }
        f64::NAN
    };
    let mut convex_ok = 0;
    let mut total = 0;
    let rs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    for i in 0..rs.len() {
        for j in (i + 2)..rs.len() {
            let mid = 0.5 * (rs[i] + rs[j]);
            let lhs = interp(mid);
            let rhs = 0.5 * (interp(rs[i]) + interp(rs[j]));
            if lhs.is_finite() && rhs.is_finite() {
                total += 1;
                if lhs <= rhs + 1e-9 {
                    convex_ok += 1;
                }
            }
        }
    }
    println!("\nmidpoint convexity held on {convex_ok}/{total} chord checks");
    assert!(convex_ok == total, "Assumption 3 convexity violated on the frontier");
}

//! Ablation A4: §V in practice — NAC-FL on *estimated* network states.
//!
//! The paper's deployment story estimates per-client BTD from the
//! arrival times of the always-sent sign bits.  This bench degrades the
//! observation with multiplicative probe noise (EWMA-smoothed) and
//! measures how much of NAC-FL's advantage over the best fixed-bit
//! policy survives — quantifying how much observation fidelity the
//! policy actually needs.

use nacfl::config::ExperimentConfig;
use nacfl::metrics::Summary;
use nacfl::netsim::estimator::ProbeEstimator;
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::sim::{simulate, simulate_observed};
use nacfl::util::rng::Rng;

fn main() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let kind = ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 };
    let seeds = 16u64;

    // Baselines: perfect observation, and the best fixed-bit policy.
    let run_exact = |spec: &str| -> Vec<f64> {
        (0..seeds)
            .map(|s| {
                let mut p = Scenario::new(kind, cfg.m)
                    .process(Rng::new(s).derive("net", 0))
                    .unwrap();
                let mut pol = parse_policy(spec).unwrap();
                simulate(&ctx, pol.as_mut(), &mut p, 300.0, 10_000_000).wall
            })
            .collect()
    };
    let fixed2 = Summary::of(&run_exact("fixed:2")).mean;
    let exact = Summary::of(&run_exact("nacfl:1")).mean;

    println!(
        "partially-correlated sigma_inf^2=4; best fixed (2-bit) mean = {fixed2:.4e}, \
         NAC-FL exact-observation mean = {exact:.4e}\n"
    );
    println!(
        "{:>12} {:>16} {:>24}",
        "probe noise", "NAC-FL mean", "advantage retained"
    );
    for noise in [0.0, 0.1, 0.25, 0.5, 1.0] {
        let times: Vec<f64> = (0..seeds)
            .map(|s| {
                let mut p = Scenario::new(kind, cfg.m)
                    .process(Rng::new(s).derive("net", 0))
                    .unwrap();
                let mut pol = parse_policy("nacfl:1").unwrap();
                let mut est =
                    ProbeEstimator::new(cfg.m, 0.5, noise, Rng::new(s).derive("probe", 0));
                simulate_observed(&ctx, pol.as_mut(), &mut p, &mut est, 300.0, 10_000_000).wall
            })
            .collect();
        let mean = Summary::of(&times).mean;
        let retained = (fixed2 - mean) / (fixed2 - exact) * 100.0;
        println!("{noise:>12} {mean:>16.4e} {retained:>22.0}%");
    }
    println!(
        "\nreading: the EWMA's smoothing lag alone (alpha = 0.5, noise = 0) costs about a\n\
         third of the advantage on time-correlated congestion; probe noise up to ~10%\n\
         is tolerable, while >= 50% makes adaptation backfire (worse than fixed-2).\n\
         Observation quality is a genuine deployment constraint — which is exactly why\n\
         the paper's section V proposes in-band probing on the always-sent sign bits\n\
         (cheap, frequent, low-noise) rather than out-of-band measurements."
    );
}

//! Ablation A3 (DESIGN.md §6): round-duration model (max vs TDMA-sum).
//!
//! The paper's simulations use d = max_j c_j s(b_j); its model setup
//! also motivates a shared-channel TDMA sum.  This bench reruns the
//! policy roster under both and shows (a) NAC-FL stays best under both,
//! and (b) under TDMA *every* client's size matters, so adaptive
//! policies compress everyone harder (lower mean bits).

use nacfl::config::ExperimentConfig;
use nacfl::exp::{cell_results, execute, ExecOptions, ExperimentPlan, RunRecord, Tier};
use nacfl::metrics::Summary;
use nacfl::netsim::{DelayModel, ScenarioKind};

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.seeds = (0..16).collect();
    cfg.scenario = ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 };

    for (name, model) in [
        ("max-delay (paper)", DelayModel::Max { theta: 0.0 }),
        ("TDMA-sum", DelayModel::TdmaSum { theta: 0.0 }),
    ] {
        cfg.delay = model;
        let plan =
            ExperimentPlan::run_cell_plan(name, &cfg, Tier::Analytic { k_eps: 300.0 });
        let summary = execute(&plan, &ExecOptions::default(), &mut []).unwrap();
        let refs: Vec<&RunRecord> = summary.records.iter().collect();
        let results = cell_results(&refs);
        println!("== {name} ==");
        let mut best = (String::new(), f64::INFINITY);
        for r in &results {
            let s = Summary::of(&r.times);
            println!(
                "  {:<12} mean {:>12.4e}  (mean rounds {:>6.0})",
                r.policy,
                s.mean,
                r.rounds.iter().sum::<usize>() as f64 / r.rounds.len() as f64
            );
            if s.mean < best.1 {
                best = (r.policy.clone(), s.mean);
            }
        }
        println!("  best: {}\n", best.0);
        assert!(
            best.0.starts_with("nacfl"),
            "NAC-FL must remain best under {name}"
        );
    }
}

//! DES core + population-scale benchmarks (DESIGN.md §15 / §9).
//!
//! Times the calendar-queue scheduler against the retained binary-heap
//! reference on the round-shaped event workload, the O(K) cohort
//! sampling path, and — the headline — `des_million_round`: a complete
//! DES campaign cell over a **million-client** population with a
//! 1000-client sampled cohort per round.  The wall clock of that
//! component witnesses the scale contract: cost per round is O(K) in
//! the cohort, never O(N) in the population (the `counters` object
//! records the rounds and sampled volume behind the timing).
//!
//! Flags (after `cargo bench --bench des_core --`):
//!   --json <path>     write the machine-readable report (BENCH_des
//!                     schema: component -> ns/op) for the perf
//!                     trajectory tracked across PRs;
//!   --budget-ms <n>   per-component wall-time budget (default 400;
//!                     CI smoke uses a tiny budget).

use nacfl::config::ExperimentConfig;
use nacfl::des::{simulate_des, DesConfig, Discipline, EventQueue, SchedulerKind};
use nacfl::netsim::ScenarioKind;
use nacfl::policy::parse_policy;
use nacfl::pop::{sample_k_of_n, CohortProcess, PopSpec};
use nacfl::util::bench::{bench, black_box, BenchJson};
use nacfl::util::rng::Rng;
use std::time::Duration;

struct Options {
    json: Option<String>,
    budget: Duration,
}

fn parse_args() -> Options {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut json = None;
    let mut budget_ms: u64 = 400;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" => {
                let Some(path) = argv.get(i + 1) else {
                    eprintln!("--json needs a path");
                    std::process::exit(2);
                };
                json = Some(path.clone());
                i += 2;
            }
            "--budget-ms" => {
                let Some(ms) = argv.get(i + 1).and_then(|s| s.parse().ok()) else {
                    eprintln!("--budget-ms needs an integer");
                    std::process::exit(2);
                };
                budget_ms = ms;
                i += 2;
            }
            // cargo bench passes --bench through to harness=false targets.
            "--bench" => i += 1,
            other => {
                eprintln!("(des_core: ignoring argument `{other}`)");
                i += 1;
            }
        }
    }
    Options { json, budget: Duration::from_millis(budget_ms.max(1)) }
}

/// One round-shaped scheduler workload: push K quantized (tie-heavy)
/// arrival times, drain them all — the event pattern of one DES round.
fn round_workload(kind: SchedulerKind, k: usize, rounds: usize) -> f64 {
    let mut q = EventQueue::with_kind(kind);
    let mut rng = Rng::new(11);
    let mut now = 0.0f64;
    for _ in 0..rounds {
        for j in 0..k {
            let dt = (rng.below(1000) as f64) * 12.5;
            q.push(now + dt, j);
        }
        let mut last = now;
        while let Some((t, _)) = q.pop() {
            last = t;
        }
        now = last + 1.0;
    }
    now
}

fn main() {
    let opts = parse_args();
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let budget = opts.budget;
    let mut report = BenchJson::new("des");
    println!("== DES scheduler core ==");

    // Scheduler shoot-out on the K=1000 round shape: the wheel's O(1)
    // amortized push/pop vs the heap's O(log n).
    const K: usize = 1000;
    let s = bench("wheel_round (K=1000 push+drain x4)", budget, || {
        black_box(round_workload(SchedulerKind::Wheel, K, 4));
    });
    println!("{}", s.report());
    report.record("wheel_round", &s);
    let s = bench("heap_round (K=1000 push+drain x4)", budget, || {
        black_box(round_workload(SchedulerKind::Heap, K, 4));
    });
    println!("{}", s.report());
    report.record("heap_round", &s);

    println!("\n== population sampling path ==");

    // Floyd's cohort sampler: K=1000 of N=10^6 per op (exactly K RNG
    // draws; O(K) time independent of N).
    let mut srng = Rng::new(3).derive("pop-sample", 1);
    let mut cohort = Vec::with_capacity(K);
    let s = bench("pop_sample (k=1000 of n=1e6)", budget, || {
        sample_k_of_n(&mut srng, 1_000_000, K, &mut cohort);
        black_box(cohort.len());
    });
    println!("{}", s.report());
    report.record("pop_sample", &s);

    // Full per-round cohort materialization: sample + class resolution +
    // per-slot BTD draws (the `next_state` the engine sees each round).
    let spec = PopSpec::parse("pop:1000000:k1000:classeshilo").unwrap();
    let mut proc_ = CohortProcess::new(
        spec,
        ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 },
        5,
    )
    .unwrap();
    let s = bench("cohort_next_state (k=1000, hilo)", budget, || {
        black_box(proc_.next_state());
    });
    println!("{}", s.report());
    report.record("cohort_next_state", &s);

    println!("\n== million-client campaign cell ==");

    // The headline: a complete DES run over pop:1000000:k1000 — every
    // round samples a fresh 1000-client cohort from the million-client
    // population and dispatches it through the calendar queue.  ns/op
    // here is the wall clock of the whole cell; the counters record the
    // rounds and sampled (client, round) volume behind it.
    let mut rounds = 0u64;
    let mut sampled = 0u64;
    let s = bench("des_million_round (pop:1000000:k1000, sync)", budget, || {
        let spec = PopSpec::parse("pop:1000000:k1000").unwrap();
        let mut p = CohortProcess::new(
            spec,
            ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 },
            3,
        )
        .unwrap();
        let mut policy = parse_policy("fixed:2").unwrap();
        let des = DesConfig::new(Discipline::Sync, 60.0);
        let r = simulate_des(&ctx, policy.as_mut(), &mut p, &des, Rng::new(1)).unwrap();
        rounds = r.rounds as u64;
        sampled = p.sampled_total();
        black_box(r.wall);
    });
    println!("{}", s.report());
    report.record("des_million_round", &s);
    report.record_counter("million_cell_rounds", rounds);
    report.record_counter("million_cell_sampled", sampled);

    if let Some(path) = &opts.json {
        report.write(path).unwrap_or_else(|e| {
            eprintln!("error writing {path}: {e}");
            std::process::exit(1);
        });
        println!("\nmachine-readable report -> {path}");
    }
}

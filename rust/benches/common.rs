//! Shared support for the paper-table bench targets.
//!
//! Each `cargo bench` target regenerates one paper artifact on the
//! analytic tier (Assumption-1 stopping rule; see `nacfl::sim`) with the
//! paper's 20 seeds, prints our rows next to the paper's published rows,
//! and times the regeneration.  Cells fan out over the work-stealing
//! grid executor (`exp::grid`), which is bit-identical to the sequential
//! runner.  `NACFL_BENCH_SEEDS` overrides the seed count;
//! `NACFL_BENCH_THREADS` pins the worker count (default: all cores);
//! `NACFL_BENCH_TIER=ml` switches to full FedCOM-V training (slow; used
//! for the recorded EXPERIMENTS.md runs).

use nacfl::config::ExperimentConfig;
use nacfl::exp::{run_cell_parallel, table_cells, table_for, Tier};

pub fn bench_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper();
    if let Ok(s) = std::env::var("NACFL_BENCH_SEEDS") {
        cfg.seeds = (0..s.parse::<u64>().expect("NACFL_BENCH_SEEDS")).collect();
    }
    cfg
}

pub fn bench_tier() -> Tier {
    match std::env::var("NACFL_BENCH_TIER").as_deref() {
        Ok("ml") => Tier::Ml,
        _ => Tier::Analytic { k_eps: 300.0 },
    }
}

pub fn bench_threads() -> usize {
    std::env::var("NACFL_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0) // 0 = all cores
}

/// Regenerate one table and print it alongside the paper's numbers.
pub fn run_table(table: &str, paper_reference: &str) {
    let cfg = bench_config();
    let tier = bench_tier();
    let threads = bench_threads();
    let started = std::time::Instant::now();
    for (label, cell_cfg) in table_cells(table, &cfg).expect("preset") {
        let t0 = std::time::Instant::now();
        let results = run_cell_parallel(&cell_cfg, tier, threads, |_, _, _| {}).expect("cell");
        let t = table_for(&label, &results).expect("table");
        println!("{}", t.render());
        println!("  (cell regenerated in {:.2?})\n", t0.elapsed());
    }
    println!("--- paper's published rows for comparison ---\n{paper_reference}");
    println!("total: {:.2?}", started.elapsed());
}

//! Shared support for the paper-table bench targets.
//!
//! Each `cargo bench` target regenerates one paper artifact on the
//! analytic tier (Assumption-1 stopping rule; see `nacfl::sim`) with the
//! paper's 20 seeds, prints our rows next to the paper's published rows,
//! and times the regeneration.  Since ISSUE-4 the cells run as
//! single-group `ExperimentPlan`s through the unified campaign engine
//! (`exp::execute` + `TableSink`), which fans runs over the
//! work-stealing pool and is bit-identical to the frozen legacy float
//! path (pinned by the `campaign_system` integration test's inline
//! reference).
//! `NACFL_BENCH_SEEDS` overrides the seed count; `NACFL_BENCH_THREADS`
//! pins the worker count (default: all cores, or `NACFL_THREADS`);
//! `NACFL_BENCH_TIER=ml` switches to full FedCOM-V training (slow; used
//! for the recorded EXPERIMENTS.md runs).

use nacfl::config::ExperimentConfig;
use nacfl::exp::{execute, table_plans, ExecOptions, TableSink, Tier};

pub fn bench_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper();
    if let Ok(s) = std::env::var("NACFL_BENCH_SEEDS") {
        cfg.seeds = (0..s.parse::<u64>().expect("NACFL_BENCH_SEEDS")).collect();
    }
    cfg
}

pub fn bench_tier() -> Tier {
    match std::env::var("NACFL_BENCH_TIER").as_deref() {
        Ok("ml") => Tier::Ml,
        _ => Tier::Analytic { k_eps: 300.0 },
    }
}

pub fn bench_threads() -> usize {
    std::env::var("NACFL_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0) // 0 = NACFL_THREADS env or all cores
}

/// Regenerate one table through the campaign engine and print it
/// alongside the paper's numbers.
pub fn run_table(table: &str, paper_reference: &str) {
    let cfg = bench_config();
    let tier = bench_tier();
    let threads = bench_threads();
    let started = std::time::Instant::now();
    for (label, plan) in table_plans(table, &cfg, tier).expect("preset") {
        let t0 = std::time::Instant::now();
        let mut sink = TableSink::new(Some(label));
        execute(&plan, &ExecOptions::with_threads(threads), &mut [&mut sink])
            .expect("cell");
        for t in &sink.tables {
            println!("{}", t.render());
        }
        println!("  (cell regenerated in {:.2?})\n", t0.elapsed());
    }
    println!("--- paper's published rows for comparison ---\n{paper_reference}");
    println!("total: {:.2?}", started.elapsed());
}

//! Regenerates paper Table II: heterogeneous independent BTD (clients
//! 1-5 fast, 6-10 slow).

#[path = "common.rs"]
mod common;

const PAPER: &str = "\
Table II (units of 1e8 s), policies [1bit 2bit 3bit FixedErr NAC-FL]:
  Mean 9.49 5.85 6.46 2.49 2.48 | 90th 11.5 7.16 8.09 3.48 3.54 | 10th 8.30 4.37 4.98 1.74 1.54 | Gain 319% 146% 173% 4% -
Reproduction target: same ordering as Table I sigma^2=1 (adaptive policies exploit
client diversity; persistent slow clients are compressed hard).";

fn main() {
    common::run_table("table2", PAPER);
}

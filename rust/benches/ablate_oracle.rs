//! Ablation A2 (DESIGN.md §6): Theorem-1 convergence.
//!
//! On a finite Markov congestion chain (Assumption 4) with a computable
//! eq.-(4) optimum, tracks NAC-FL's running-estimate objective
//! r_hat * d_hat and its realized wall-clock rate against the oracle's,
//! plus NAC-FL's alpha sensitivity (alpha = 1 is the calibrated value
//! for our analytic variance model; see DESIGN.md §6 note).

use nacfl::config::ExperimentConfig;
use nacfl::netsim::{MarkovChain, NetworkProcess};
use nacfl::policy::{CompressionPolicy, NacFl, OraclePolicy};
use nacfl::util::rng::Rng;

fn main() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let m = cfg.m;
    let mut srng = Rng::new(21);
    let states: Vec<Vec<f64>> = (0..8)
        .map(|_| (0..m).map(|_| srng.normal_ms(1.0, 1.0).exp()).collect())
        .collect();
    let chain = MarkovChain::uniform_mixing(states, 0.4, Rng::new(4)).unwrap();
    let oracle = OraclePolicy::solve(&ctx, &chain);
    println!(
        "oracle (eq. 4): E[rho] = {:.4}, E[d] = {:.4e}, objective = {:.4e}\n",
        oracle.expected_rho,
        oracle.expected_d,
        oracle.objective()
    );

    println!("{:>8} {:>14} {:>10}   (NAC-FL alpha = 1, beta_n = 1/n)", "rounds", "r_hat*d_hat", "gap");
    let mut nac = NacFl::new(1.0);
    let mut c2 = chain.clone();
    for n in 1..=50_000usize {
        let c = c2.next_state();
        nac.choose(&ctx, &c);
        if [10usize, 50, 200, 1000, 5000, 50_000].contains(&n) {
            let (r, d) = nac.estimates();
            println!(
                "{n:>8} {:>14.4e} {:>9.2}%",
                r * d,
                (r * d / oracle.objective() - 1.0) * 100.0
            );
        }
    }

    println!("\nalpha sensitivity (objective after 20k rounds; optimum = eq. 4):");
    for alpha in [0.5, 1.0, 2.0, 4.0] {
        let mut nac = NacFl::new(alpha);
        let mut c3 = chain.clone();
        for _ in 0..20_000 {
            let c = c3.next_state();
            nac.choose(&ctx, &c);
        }
        let (r, d) = nac.estimates();
        println!(
            "  alpha = {alpha:<4} -> r_hat*d_hat = {:.4e} (gap {:+.2}%)",
            r * d,
            (r * d / oracle.objective() - 1.0) * 100.0
        );
    }
    println!("\nalpha = 1 recovers the Frank-Wolfe objective exactly (Theorem 1); alpha != 1\nbiases toward duration (>1) or rounds (<1) — the paper tunes alpha = 2 for its\nempirically-calibrated h_eps, ours is analytic so alpha = 1 is the equivalent.");
}

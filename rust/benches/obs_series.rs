//! Observability-layer benchmarks (DESIGN.md §16).
//!
//! Times the round-series recorder — the one call every instrumented
//! engine round pays with `--series` on — plus the disabled-handle
//! no-op (the cost the *default* path pays), the bounded series-line
//! serialization, and the trace recorder's duration-event push.  The
//! `counters` object records the rounds fed through the recorder and
//! the stride its decimation settled on, witnessing the O(cap) storage
//! contract behind the timing.
//!
//! Flags (after `cargo bench --bench obs_series --`):
//!   --json <path>     write the machine-readable report (BENCH_obs
//!                     schema: component -> ns/op) for the perf
//!                     trajectory tracked across PRs;
//!   --budget-ms <n>   per-component wall-time budget (default 400;
//!                     CI smoke uses a tiny budget).

use nacfl::obs::{RoundSeries, Sample, TraceRecorder, SERIES_CAP};
use nacfl::util::bench::{bench, black_box, BenchJson};
use std::time::Duration;

struct Options {
    json: Option<String>,
    budget: Duration,
}

fn parse_args() -> Options {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut json = None;
    let mut budget_ms: u64 = 400;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" => {
                let Some(path) = argv.get(i + 1) else {
                    eprintln!("--json needs a path");
                    std::process::exit(2);
                };
                json = Some(path.clone());
                i += 2;
            }
            "--budget-ms" => {
                let Some(ms) = argv.get(i + 1).and_then(|s| s.parse().ok()) else {
                    eprintln!("--budget-ms needs an integer");
                    std::process::exit(2);
                };
                budget_ms = ms;
                i += 2;
            }
            // cargo bench passes --bench through to harness=false targets.
            "--bench" => i += 1,
            other => {
                eprintln!("(obs_series: ignoring argument `{other}`)");
                i += 1;
            }
        }
    }
    Options { json, budget: Duration::from_millis(budget_ms.max(1)) }
}

fn main() {
    let opts = parse_args();
    let budget = opts.budget;
    let mut report = BenchJson::new("obs");
    println!("== round-series recorder ==");

    // The per-round record cost, amortized across decimation passes:
    // the recorder keeps absorbing rounds while stride doubling holds
    // the kept set at <= SERIES_CAP, so ns/op here is exactly what an
    // engine round pays with `--series` on.
    let mut series = RoundSeries::on();
    let mut sample = Sample::default();
    let mut round = 0u64;
    let s = bench("series_record (amortized per round)", budget, || {
        sample.level_mean = (round % 16) as f64;
        sample.wire_bits = 1.0e6 + round as f64;
        sample.wall_s = round as f64;
        series.record(sample);
        round += 1;
    });
    println!("{}", s.report());
    report.record("series_record", &s);
    report.record_counter("series_rounds_recorded", series.rounds_total());
    report.record_counter("series_stride", series.stride());
    report.record_counter("series_kept", series.len() as u64);
    assert!(series.len() <= SERIES_CAP, "decimation must hold the cap");

    // The disabled handle: a single branch on None.  This is the
    // overhead every default (series-off) engine round carries.
    let mut off = RoundSeries::off();
    let s = bench("series_record_off (disabled handle)", budget, || {
        off.record(black_box(sample));
    });
    println!("{}", s.report());
    report.record("series_record_off", &s);

    // One ledger line from a full recorder: <= SERIES_CAP kept rounds
    // across 12 channels, flat JSON.
    let s = bench("series_line_json (<=128 kept rounds)", budget, || {
        black_box(series.line("bench|cell").unwrap().to_json().len());
    });
    println!("{}", s.report());
    report.record("series_line_json", &s);

    println!("\n== event-trace recorder ==");

    // Duration-event push on a warm recorder, cycled well under the
    // event cap so every op takes the real record path (never the
    // cheaper dropped-counter branch).
    let mut tracer = TraceRecorder::on();
    let mut i = 0u32;
    let s = bench("trace_upload (duration event)", budget, || {
        if i == 4096 {
            tracer = TraceRecorder::on();
            i = 0;
        }
        tracer.upload(3, i as f64, 1000.0);
        i += 1;
    });
    println!("{}", s.report());
    report.record("trace_upload", &s);
    assert_eq!(tracer.dropped(), 0, "cycling must stay under the cap");

    if let Some(path) = &opts.json {
        report.write(path).unwrap_or_else(|e| {
            eprintln!("error writing {path}: {e}");
            std::process::exit(1);
        });
        println!("\nmachine-readable report -> {path}");
    }
}

//! Regenerates the paper's Fig. 1 illustration: how the compression
//! level trades off rounds-to-converge against round duration, with the
//! wall clock (their product) minimized at an interior sweet spot.
//!
//! Sweeps fixed bit-widths b = 1..12 under the homogeneous scenario and
//! prints the three curves (expected rounds proxy, mean round duration,
//! mean wall clock).

use nacfl::config::ExperimentConfig;
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::sim::simulate;
use nacfl::util::rng::Rng;

fn main() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let seeds = 20u64;
    println!(
        "{:>4} {:>14} {:>16} {:>16}   (Fig. 1: rounds ^ with compression, duration v, wall U-shaped)",
        "b", "rounds", "mean duration", "wall clock"
    );
    let mut best = (0u8, f64::INFINITY);
    for b in 1..=12u8 {
        let (mut rounds, mut wall) = (0.0, 0.0);
        for s in 0..seeds {
            let sc = Scenario::new(ScenarioKind::HomogeneousIndependent { sigma_sq: 1.0 }, cfg.m);
            let mut p = sc.process(Rng::new(s).derive("net", 0)).unwrap();
            let mut pol = parse_policy(&format!("fixed:{b}")).unwrap();
            let r = simulate(&ctx, pol.as_mut(), &mut p, 300.0, 10_000_000);
            rounds += r.rounds as f64;
            wall += r.wall;
        }
        rounds /= seeds as f64;
        wall /= seeds as f64;
        println!("{:>4} {:>14.1} {:>16.4e} {:>16.4e}", b, rounds, wall / rounds, wall);
        if wall < best.1 {
            best = (b, wall);
        }
    }
    println!("\nsweet spot at b = {} — an interior optimum, as Fig. 1 illustrates", best.0);
    assert!(
        (2..=8).contains(&best.0),
        "wall clock should be minimized at an interior compression level"
    );
}

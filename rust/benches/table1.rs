//! Regenerates paper Table I: homogeneous independent BTD,
//! sigma^2 in {1, 2, 3} — mean / 90th / 10th time-to-target + gain.

#[path = "common.rs"]
mod common;

const PAPER: &str = "\
Table I (units of 1e7 s), policies [1bit 2bit 3bit FixedErr NAC-FL]:
  s2=1: Mean 6.31 3.82 4.15 1.58 1.60 | 90th 6.95 4.72 5.00 1.86 2.05 | 10th 5.63 3.20 3.38 1.20 1.14 | Gain 314% 145% 168% 3% -
  s2=2: Mean 54.8 32.5 34.9 12.5 12.2 | 90th 70.6 44.7 43.1 19.0 20.8 | 10th 42.5 19.2 21.0 6.26 5.82 | Gain 522% 216% 240% 8% -
  s2=3: Mean 799  430  458  165  168  | 90th 1430 752  665  318  320  | 10th 418  157  148  46.2 57.9 | Gain 881% 270% 250% 1% -
Reproduction target: ordering (NAC-FL ~ FixedError << FixedBit), gap widening with sigma^2.";

fn main() {
    common::run_table("table1", PAPER);
}

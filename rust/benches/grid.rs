//! Grid executor bench: the campaign engine single-threaded vs the
//! work-stealing parallel fan-out, plus DES discipline throughput.
//!
//! Prints the measured wall-clock speedup of the parallel engine (the
//! acceptance target is >= 2x on a 4-core host) and verifies en route
//! that every thread count renders bit-identical tables.
//! `NACFL_BENCH_SEEDS` scales the cell; `NACFL_BENCH_THREADS` pins the
//! parallel worker count.

use nacfl::config::ExperimentConfig;
use nacfl::des::{simulate_des, DesConfig, Discipline, FaultModel};
use nacfl::exp::{execute, resolve_threads, ExecOptions, ExperimentPlan, TableSink, Tier};
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::util::rng::Rng;
use std::time::Instant;

fn main() {
    let mut cfg = ExperimentConfig::paper();
    let seeds: u64 = std::env::var("NACFL_BENCH_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    cfg.seeds = (0..seeds).collect();
    cfg.scenario = ScenarioKind::HomogeneousIndependent { sigma_sq: 2.0 };
    let tier = Tier::Analytic { k_eps: 300.0 };
    // 0 = resolve to all cores, same convention as the engine.
    let threads = resolve_threads(
        std::env::var("NACFL_BENCH_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0),
    );

    println!(
        "== grid sweep: {} policies x {} seeds, k_eps = 300 ==",
        cfg.policies.len(),
        cfg.seeds.len()
    );
    let plan = ExperimentPlan::run_cell_plan("grid bench", &cfg, tier);
    let run = |threads: usize| {
        let mut sink = TableSink::new(Some("grid bench".to_string()));
        execute(&plan, &ExecOptions::with_threads(threads), &mut [&mut sink])
            .expect("engine cell");
        sink.tables[0].render()
    };

    let t0 = Instant::now();
    let seq_table = run(1);
    let t_seq = t0.elapsed();
    println!("engine, 1 thread:          {t_seq:>10.2?}");

    let t1 = Instant::now();
    let par_table = run(threads);
    let t_par = t1.elapsed();
    println!("engine, {threads} threads:         {t_par:>10.2?}");

    // Bit-identity gate: the speedup is only meaningful if the tables match.
    assert_eq!(
        seq_table, par_table,
        "parallel table must be bit-identical to single-threaded"
    );
    let speedup = t_seq.as_secs_f64() / t_par.as_secs_f64().max(1e-9);
    println!("speedup: {speedup:.2}x (bit-identical tables verified; target >= 2x on 4 cores)");

    // DES discipline throughput on one straggler-heavy cell.
    println!("\n== DES disciplines: heterog + stragglers(8,9 x8), fixed:2, seed 0 ==");
    let ctx = cfg.policy_ctx();
    let faults = FaultModel::none().with_stragglers(cfg.m, &[8, 9], 8.0);
    for d in [
        Discipline::Sync,
        Discipline::SemiSync { k: 7 },
        Discipline::Async { staleness_exp: 0.5 },
    ] {
        let mut policy = parse_policy("fixed:2").expect("policy");
        let mut process = Scenario::new(ScenarioKind::HeterogeneousIndependent, cfg.m)
            .process(Rng::new(0).derive("net", 0))
            .expect("process");
        let des = DesConfig::new(d, 300.0).with_faults(faults.clone());
        let t = Instant::now();
        let r = simulate_des(&ctx, policy.as_mut(), &mut process, &des, Rng::new(17))
            .expect("des run");
        println!(
            "{:<14} wall {:>10.3e} s  rounds {:>6}  mean round {:>10.3e} s  ({:.2?} real)",
            d.label(),
            r.wall,
            r.rounds,
            r.mean_round_duration(),
            t.elapsed()
        );
    }
}

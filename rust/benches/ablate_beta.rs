//! Ablation A1 (DESIGN.md §6): NAC-FL step-size schedule.
//!
//! The paper derives the algorithm with a constant Frank-Wolfe step beta
//! (§III-C / Theorem 1) but runs beta_n = 1/n in simulation.  This bench
//! compares both on stationary and *regime-switching* congestion — the
//! harmonic schedule wins when the environment is stationary, while a
//! constant step keeps adapting after a distribution shift.

use nacfl::config::ExperimentConfig;
use nacfl::metrics::Summary;
use nacfl::netsim::btd::{IidLogNormal, NetworkProcess};
use nacfl::policy::nacfl::{NacFl, StepSize};
use nacfl::sim::simulate;
use nacfl::util::rng::Rng;

/// A process whose mean BTD jumps by 8x halfway through a horizon.
struct RegimeSwitch {
    inner: IidLogNormal,
    n: usize,
    switch_at: usize,
}

impl NetworkProcess for RegimeSwitch {
    fn dim(&self) -> usize {
        self.inner.m
    }
    fn next_state(&mut self) -> Vec<f64> {
        self.n += 1;
        let mut c = self.inner.next_state();
        if self.n > self.switch_at {
            for v in c.iter_mut() {
                *v *= 8.0;
            }
        }
        c
    }
}

fn run(step: StepSize, switching: bool, seeds: u64) -> Vec<f64> {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    (0..seeds)
        .map(|s| {
            let inner = IidLogNormal { m: cfg.m, mu: 1.0, sigma: 1.0, rng: Rng::new(s) };
            let mut pol = NacFl::with_step(1.0, step);
            if switching {
                let mut p = RegimeSwitch { inner, n: 0, switch_at: 150 };
                simulate(&ctx, &mut pol, &mut p, 300.0, 10_000_000).wall
            } else {
                let mut p = inner;
                simulate(&ctx, &mut pol, &mut p, 300.0, 10_000_000).wall
            }
        })
        .collect()
}

fn main() {
    println!("{:<28} {:>16} {:>16}", "schedule", "stationary mean", "regime-switch mean");
    let mut rows = Vec::new();
    for (name, step) in [
        ("beta_n = 1/n (paper)", StepSize::Harmonic),
        ("beta = 0.2", StepSize::Constant(0.2)),
        ("beta = 0.05", StepSize::Constant(0.05)),
        ("beta = 0.01", StepSize::Constant(0.01)),
    ] {
        let stat = Summary::of(&run(step, false, 16)).mean;
        let shift = Summary::of(&run(step, true, 16)).mean;
        println!("{name:<28} {stat:>16.4e} {shift:>16.4e}");
        rows.push((name, stat, shift));
    }
    let harmonic = rows[0];
    let best_const_shift = rows[1..]
        .iter()
        .map(|r| r.2)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nharmonic wins when stationary; under a regime switch the best constant \
         step is {:.1}% {} than harmonic — the tracking/variance trade-off the \
         paper's Section III-C remark alludes to.",
        ((harmonic.2 / best_const_shift) - 1.0).abs() * 100.0,
        if best_const_shift < harmonic.2 { "faster" } else { "slower" }
    );
}

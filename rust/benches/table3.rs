//! Regenerates paper Table III: perfectly correlated BTD,
//! sigma_inf^2 in {1.56, 4, 16} — the paper's headline case where
//! NAC-FL's time-adaptivity separates it from Fixed-Error.

#[path = "common.rs"]
mod common;

const PAPER: &str = "\
Table III (units of 1e7 s), policies [1bit 2bit 3bit FixedErr NAC-FL]:
  si2=1.56: Mean 5.14 3.04 3.47 2.21 2.11 | 90th 5.94 3.65 4.43 2.66 3.32 | 10th 3.88 2.38 2.18 1.43 1.02 | Gain 191% 58% 75% 13% -
  si2=4:    Mean 5.82 3.49 4.03 2.47 2.23 | 90th 7.43 4.77 6.28 3.94 4.00 | 10th 3.88 2.22 1.98 1.21 0.98 | Gain 252% 82% 107% 27% -
  si2=16:   Mean 8.42 5.19 6.15 3.75 3.36 | 90th 12.8 10.3 13.4 7.94 7.2  | 10th 4.34 1.40 1.67 1.15 0.87 | Gain 316% 72% 98% 21% -
Reproduction target: NAC-FL gain over Fixed-Error positive and larger than the
independent-BTD case, growing with sigma_inf^2.";

fn main() {
    common::run_table("table3", PAPER);
}

//! P1 (DESIGN.md §6 / §9): hot-path microbenchmarks.
//!
//! Times every component on the per-round path, per layer:
//!   L3  policy argmin (eq. 6) — workspace fast path AND the retained
//!       direct reference (so one run shows the solver speedup),
//!       Fixed-Error solver (both paths), TDMA coordinate descent,
//!       netsim step, rust quantizer (throughput), top-k water-filling
//!       sparsifier (throughput), aggregation reduce;
//!   L2/L1 (via PJRT) local_round / quantize / global_step / eval_chunk
//!       graph executions, plus an end-to-end threaded coordinator round.
//!
//! Flags (after `cargo bench --bench hotpath --`):
//!   --json <path>     write the machine-readable report (BENCH_hotpath
//!                     schema: component -> ns/op, GB/s) for the perf
//!                     trajectory tracked across PRs (see DESIGN.md §9);
//!   --budget-ms <n>   per-component wall-time budget (default 400;
//!                     CI smoke uses a tiny budget).

use nacfl::config::ExperimentConfig;
use nacfl::coordinator::{Coordinator, FailureConfig};
use nacfl::des::{simulate_des, DesConfig, Discipline, FaultModel};
use nacfl::data::synth::{generate, SynthConfig};
use nacfl::data::{partition, PartitionKind};
use nacfl::fl::engine::{make_engine, ComputeEngine, RustEngine};
use nacfl::model::{Mlp, MlpDims};
use nacfl::netsim::{DelayModel, FlowNet, FlowPreset, NetworkProcess, Scenario, ScenarioKind};
use nacfl::obs::Telemetry;
use nacfl::policy::solver::{reference, SolverWorkspace};
use nacfl::policy::{parse_policy, CompressionPolicy, NacFl, PolicyCtx};
use nacfl::quant::stochastic::quantize_into;
use nacfl::quant::{Compressor, TopKSparsifier};
use nacfl::runtime::{dims, Runtime};
use nacfl::util::bench::{bench, black_box, BenchJson};
use nacfl::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

struct Options {
    json: Option<String>,
    budget: Duration,
}

fn parse_args() -> Options {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut json = None;
    let mut budget_ms: u64 = 400;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" => {
                let Some(path) = argv.get(i + 1) else {
                    eprintln!("--json needs a path");
                    std::process::exit(2);
                };
                json = Some(path.clone());
                i += 2;
            }
            "--budget-ms" => {
                let Some(ms) = argv.get(i + 1).and_then(|s| s.parse().ok()) else {
                    eprintln!("--budget-ms needs an integer");
                    std::process::exit(2);
                };
                budget_ms = ms;
                i += 2;
            }
            // cargo bench passes --bench through to harness=false targets.
            "--bench" => i += 1,
            other => {
                eprintln!("(hotpath: ignoring argument `{other}`)");
                i += 1;
            }
        }
    }
    Options { json, budget: Duration::from_millis(budget_ms.max(1)) }
}

fn main() {
    let opts = parse_args();
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let budget = opts.budget;
    let mut report = BenchJson::new("hotpath");
    let mut rng = Rng::new(0);
    println!("== L3 coordinator hot path ==");

    // Policy argmin (eq. 6), m = 10.
    let c: Vec<f64> = (0..cfg.m).map(|_| rng.normal_ms(1.0, 1.0).exp()).collect();
    let mut nac = NacFl::new(1.0);
    nac.choose(&ctx, &c); // warm estimates
    let (r_hat, d_hat) = nac.estimates();
    // Persistent warmed instance: times the per-round choose (solve +
    // estimate update) without paying a policy clone per iteration —
    // with beta_n = 1/n the estimates are stationary after warm-up.
    let mut p = nac.clone();
    let s = bench("nacfl_choose (eq.6 argmin, m=10)", budget, || {
        black_box(p.choose(&ctx, &c));
    });
    println!("{}", s.report());
    report.record("nacfl_choose", &s);

    // The same choose with telemetry enabled (solver timing on): the
    // delta vs `nacfl_choose` is the observability overhead budget
    // (DESIGN.md §12), and the solver counters give the workload size
    // behind every ns/op in this file.
    let mut pt = nac.clone();
    pt.set_telemetry(true);
    let s = bench("nacfl_choose (telemetry on, m=10)", budget, || {
        black_box(pt.choose(&ctx, &c));
    });
    println!("{}", s.report());
    report.record("nacfl_choose_telemetry", &s);
    if let Some(st) = pt.solver_stats() {
        report.record_counter("solver_solves", st.solves);
        report.record_counter("solver_sweep_candidates", st.candidates);
        report.record_counter("solver_solve_ns", st.ns);
    }

    // The solver alone: workspace event sweep vs the retained direct
    // reference (same warmed coefficients), so this run witnesses the
    // allocation-free speedup directly.
    let (a_coef, b_coef) = (r_hat, d_hat);
    let mut ws = SolverWorkspace::new();
    let s = bench("argmin_max (workspace, m=10)", budget, || {
        black_box(ws.argmin_cost(&ctx, &c, a_coef, b_coef));
    });
    println!("{}", s.report());
    report.record("argmin_max_workspace", &s);
    let s = bench("argmin_max (reference, m=10)", budget, || {
        black_box(reference::argmin_cost(&ctx, &c, a_coef, b_coef));
    });
    println!("{}", s.report());
    report.record("argmin_max_reference", &s);

    let s = bench("fixed_error_solver (m=10)", budget, || {
        black_box(ws.min_duration_with_error_budget(&ctx, &c, 5.25));
    });
    println!("{}", s.report());
    report.record("fixed_error_solver", &s);
    let s = bench("fixed_error (reference, m=10)", budget, || {
        black_box(reference::min_duration_with_error_budget(&ctx, &c, 5.25));
    });
    println!("{}", s.report());
    report.record("fixed_error_reference", &s);

    // TDMA coordinate descent (running-sum moves vs O(m) re-pricing).
    let ctx_tdma = PolicyCtx::new(
        cfg.tau,
        DelayModel::TdmaSum { theta: 0.0 },
        Arc::clone(&ctx.compressor),
    );
    let s = bench("argmin_tdma (workspace, m=10)", budget, || {
        black_box(ws.argmin_cost(&ctx_tdma, &c, a_coef, b_coef));
    });
    println!("{}", s.report());
    report.record("argmin_tdma_workspace", &s);
    let s = bench("argmin_tdma (reference, m=10)", budget, || {
        black_box(reference::argmin_cost(&ctx_tdma, &c, a_coef, b_coef));
    });
    println!("{}", s.report());
    report.record("argmin_tdma_reference", &s);

    // Congestion process step.
    let sc = Scenario::new(ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 }, cfg.m);
    let mut proc = sc.process(Rng::new(1)).unwrap();
    let s = bench("netsim_step (AR(1) m=10)", budget, || {
        black_box(proc.next_state());
    });
    println!("{}", s.report());
    report.record("netsim_step", &s);

    // Faulty DES rounds (DESIGN.md §14): an 8-round event-engine
    // simulation under packet loss with retransmission, a round
    // deadline with quorum, and crash-recover clients — prices the
    // fault machinery (attempt draws, backoff scheduling, deadline
    // cuts, crash windows) on top of the plain per-round path.
    let fault_cfg = DesConfig::new(Discipline::Sync, 50.0)
        .with_faults(
            FaultModel::parse("loss:0.1:retry2+deadline:4000000:quorum0.5+crash:40000000x4000000")
                .unwrap(),
        )
        .with_max_rounds(8);
    let mut fault_pol = parse_policy("fixed:2").unwrap();
    let s = bench("des_fault_round (loss+deadline+crash, 8-round sim)", budget, || {
        let mut fproc = sc.process(Rng::new(7)).unwrap();
        black_box(
            simulate_des(&ctx, fault_pol.as_mut(), &mut fproc, &fault_cfg, Rng::new(8)).unwrap(),
        );
    });
    println!("{}", s.report());
    report.record("des_fault_round", &s);

    // Flow-network fair-share allocator (DESIGN.md §13): one fully
    // contended round on a 4x16 tower topology — begin_round, admit
    // all 64 uploads, drain every completion through the repricer.
    let preset = FlowPreset::parse("tower:4x16").unwrap();
    let m_flow = 64usize;
    let jobs: Vec<(f64, f64)> = {
        let mut jrng = Rng::new(5);
        (0..m_flow)
            .map(|_| (1000.0 * (1.0 + jrng.uniform()), 0.5 + 4.0 * jrng.uniform()))
            .collect()
    };
    let frng = Rng::new(6);
    let mut net = FlowNet::new(&preset, m_flow, &frng, 1.0).unwrap();
    let mut telem = Telemetry::off();
    let s = bench("flow_fair_share (tower:4x16, m=64 round)", budget, || {
        net.begin_round(0.0, &mut telem);
        for (j, &(bits, solo)) in jobs.iter().enumerate() {
            net.admit(j, bits, solo, &mut telem);
        }
        let mut last = 0.0f64;
        while let Some((t, _, _)) = net.next_completion(&mut telem) {
            last = t;
        }
        black_box(last);
    });
    println!("{}", s.report());
    report.record("flow_fair_share", &s);

    // Rust quantizer throughput on a full update vector.
    let v: Vec<f32> = (0..dims::P).map(|_| rng.normal() as f32).collect();
    let mut out = vec![0.0f32; dims::P];
    let mut qrng = Rng::new(2);
    let s = bench("quantize_into (rust, P=198,760)", budget, || {
        black_box(quantize_into(&v, 3.0, &mut qrng, &mut out));
    });
    println!("{} [{:.2} GB/s]", s.report(), s.throughput(dims::P * 4) / 1e9);
    report.record_throughput("quantize_into", &s, dims::P * 4);

    // Top-k water-filling sparsifier (select_nth-based threshold).
    let topk = TopKSparsifier::new(dims::P, 0.05).unwrap();
    let mut trng = Rng::new(4);
    let s = bench("topk_compress (frac=0.05, P)", budget, || {
        black_box(topk.compress_into(&v, 1, &mut trng, &mut out));
    });
    println!("{} [{:.2} GB/s]", s.report(), s.throughput(dims::P * 4) / 1e9);
    report.record_throughput("topk_compress", &s, dims::P * 4);

    // Aggregation reduce (m adds over P).
    let dqs: Vec<Vec<f32>> = (0..cfg.m).map(|_| v.clone()).collect();
    let mut agg = vec![0.0f32; dims::P];
    let s = bench("aggregate_reduce (m=10, P)", budget, || {
        agg.fill(0.0);
        for dq in &dqs {
            for (a, &x) in agg.iter_mut().zip(dq.iter()) {
                *a += x * 0.1;
            }
        }
        black_box(agg[0]);
    });
    println!("{}", s.report());
    report.record("aggregate_reduce", &s);

    // Rust engine local round (fallback compute).
    let mut re = RustEngine::new();
    let d = re.dims();
    let mlp = Mlp::new(MlpDims::paper());
    let w = mlp.init_params(&mut rng);
    let xs: Vec<f32> = (0..d.tau * d.batch * d.d_in).map(|_| rng.uniform_f32()).collect();
    let ys: Vec<i32> = (0..d.tau * d.batch).map(|i| (i % 10) as i32).collect();
    let s = bench("local_round (rust engine)", budget, || {
        black_box(re.local_round(&w, &xs, &ys, 0.07).unwrap());
    });
    println!("{}", s.report());
    report.record("local_round_rust", &s);

    // PJRT path (skipped without artifacts).
    if Runtime::artifacts_present("artifacts") {
        println!("\n== L2/L1 via PJRT (AOT artifacts) ==");
        let mut xe = make_engine("xla", "artifacts").unwrap();
        let s = bench("local_round (xla engine)", budget, || {
            black_box(xe.local_round(&w, &xs, &ys, 0.07).unwrap());
        });
        println!("{}", s.report());
        report.record("local_round_xla", &s);
        let mut u = vec![0.0f32; d.p];
        rng.fill_uniform_f32(&mut u);
        let upd = xe.local_round(&w, &xs, &ys, 0.07).unwrap();
        let s = bench("quantize (xla graph, P)", budget, || {
            black_box(xe.quantize(&upd, 3.0, &u).unwrap());
        });
        println!("{} [{:.2} GB/s]", s.report(), s.throughput(dims::P * 4) / 1e9);
        report.record_throughput("quantize_xla", &s, dims::P * 4);
        let s = bench("global_step (xla graph, P)", budget, || {
            black_box(xe.global_step(&w, &upd, 0.07).unwrap());
        });
        println!("{}", s.report());
        report.record("global_step_xla", &s);
        let ex: Vec<f32> = (0..d.eval_chunk * d.d_in).map(|_| rng.uniform_f32()).collect();
        let ey: Vec<i32> = (0..d.eval_chunk).map(|i| (i % 10) as i32).collect();
        let s = bench("eval_chunk (xla graph, 1000 rows)", budget, || {
            black_box(xe.eval_chunk(&w, &ex, &ey).unwrap());
        });
        println!("{}", s.report());
        report.record("eval_chunk_xla", &s);

        // End-to-end threaded round (the real per-round cost).
        println!("\n== end-to-end coordinator round (threaded, xla) ==");
        let mut cfg2 = cfg.clone();
        cfg2.train_n = 4000;
        cfg2.test_n = 1000;
        cfg2.max_rounds = 8;
        cfg2.eval_every = 1000; // no eval inside the timed window
        cfg2.target_acc = 2.0;
        let train = Arc::new(generate(cfg2.train_n, 0, &SynthConfig::default()));
        let test = Arc::new(generate(cfg2.test_n, 1, &SynthConfig::default()));
        let part = partition(&train, cfg2.m, PartitionKind::Heterogeneous, 0);
        let t0 = std::time::Instant::now();
        let mut co =
            Coordinator::new(&cfg2, train, test, &part, 0, &FailureConfig::default()).unwrap();
        let setup = t0.elapsed();
        let mut pol = parse_policy("nacfl:1").unwrap();
        let mut proc = sc.process(Rng::new(3)).unwrap();
        let t1 = std::time::Instant::now();
        co.run(pol.as_mut(), &mut proc).unwrap();
        let per_round = t1.elapsed() / cfg2.max_rounds as u32;
        println!(
            "coordinator: setup (PJRT client(s) + compile) {setup:.2?}; \
             {} rounds -> {per_round:.2?}/round",
            cfg2.max_rounds
        );
    } else {
        println!("\n(artifacts missing: PJRT benches skipped — run `make artifacts`)");
    }

    if let Some(path) = &opts.json {
        report.write(path).unwrap_or_else(|e| {
            eprintln!("error writing {path}: {e}");
            std::process::exit(1);
        });
        println!("\nmachine-readable report -> {path}");
    }
}

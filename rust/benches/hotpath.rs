//! P1 (DESIGN.md §6 / §Perf): hot-path microbenchmarks.
//!
//! Times every component on the per-round path, per layer:
//!   L3  policy argmin (eq. 6), Fixed-Error solver, netsim step,
//!       rust quantizer (throughput), aggregation reduce;
//!   L2/L1 (via PJRT) local_round / quantize / global_step / eval_chunk
//!       graph executions, plus an end-to-end threaded coordinator round.
//!
//! Results feed EXPERIMENTS.md §Perf (before/after optimization log).

use nacfl::config::ExperimentConfig;
use nacfl::coordinator::{Coordinator, FailureConfig};
use nacfl::data::synth::{generate, SynthConfig};
use nacfl::data::{partition, PartitionKind};
use nacfl::fl::engine::{make_engine, ComputeEngine, RustEngine};
use nacfl::model::{Mlp, MlpDims};
use nacfl::netsim::{NetworkProcess, Scenario, ScenarioKind};
use nacfl::policy::{parse_policy, solver, CompressionPolicy, NacFl};
use nacfl::quant::stochastic::quantize_into;
use nacfl::runtime::{dims, Runtime};
use nacfl::util::bench::{bench, black_box};
use nacfl::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let budget = Duration::from_millis(400);
    let mut rng = Rng::new(0);
    println!("== L3 coordinator hot path ==");

    // Policy argmin (eq. 6), m = 10.
    let c: Vec<f64> = (0..cfg.m).map(|_| rng.normal_ms(1.0, 1.0).exp()).collect();
    let mut nac = NacFl::new(1.0);
    nac.choose(&ctx, &c); // warm estimates
    let s = bench("nacfl_choose (eq.6 argmin, m=10)", budget, || {
        let mut p = nac.clone();
        black_box(p.choose(&ctx, &c));
    });
    println!("{}", s.report());

    let s = bench("fixed_error_solver (m=10)", budget, || {
        black_box(solver::min_duration_with_error_budget(&ctx, &c, 5.25));
    });
    println!("{}", s.report());

    // Congestion process step.
    let sc = Scenario::new(ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 }, cfg.m);
    let mut proc = sc.process(Rng::new(1)).unwrap();
    let s = bench("netsim_step (AR(1) m=10)", budget, || {
        black_box(proc.next_state());
    });
    println!("{}", s.report());

    // Rust quantizer throughput on a full update vector.
    let v: Vec<f32> = (0..dims::P).map(|_| rng.normal() as f32).collect();
    let mut out = vec![0.0f32; dims::P];
    let mut qrng = Rng::new(2);
    let s = bench("quantize_into (rust, P=198,760)", budget, || {
        black_box(quantize_into(&v, 3.0, &mut qrng, &mut out));
    });
    println!("{} [{:.2} GB/s]", s.report(), s.throughput(dims::P * 4) / 1e9);

    // Aggregation reduce (m adds over P).
    let dqs: Vec<Vec<f32>> = (0..cfg.m).map(|_| v.clone()).collect();
    let mut agg = vec![0.0f32; dims::P];
    let s = bench("aggregate_reduce (m=10, P)", budget, || {
        agg.fill(0.0);
        for dq in &dqs {
            for (a, &x) in agg.iter_mut().zip(dq.iter()) {
                *a += x * 0.1;
            }
        }
        black_box(agg[0]);
    });
    println!("{}", s.report());

    // Rust engine local round (fallback compute).
    let mut re = RustEngine::new();
    let d = re.dims();
    let mlp = Mlp::new(MlpDims::paper());
    let w = mlp.init_params(&mut rng);
    let xs: Vec<f32> = (0..d.tau * d.batch * d.d_in).map(|_| rng.uniform_f32()).collect();
    let ys: Vec<i32> = (0..d.tau * d.batch).map(|i| (i % 10) as i32).collect();
    let s = bench("local_round (rust engine)", budget, || {
        black_box(re.local_round(&w, &xs, &ys, 0.07).unwrap());
    });
    println!("{}", s.report());

    // PJRT path (skipped without artifacts).
    if Runtime::artifacts_present("artifacts") {
        println!("\n== L2/L1 via PJRT (AOT artifacts) ==");
        let mut xe = make_engine("xla", "artifacts").unwrap();
        let s = bench("local_round (xla engine)", budget, || {
            black_box(xe.local_round(&w, &xs, &ys, 0.07).unwrap());
        });
        println!("{}", s.report());
        let mut u = vec![0.0f32; d.p];
        rng.fill_uniform_f32(&mut u);
        let upd = xe.local_round(&w, &xs, &ys, 0.07).unwrap();
        let s = bench("quantize (xla graph, P)", budget, || {
            black_box(xe.quantize(&upd, 3.0, &u).unwrap());
        });
        println!("{} [{:.2} GB/s]", s.report(), s.throughput(dims::P * 4) / 1e9);
        let s = bench("global_step (xla graph, P)", budget, || {
            black_box(xe.global_step(&w, &upd, 0.07).unwrap());
        });
        println!("{}", s.report());
        let ex: Vec<f32> = (0..d.eval_chunk * d.d_in).map(|_| rng.uniform_f32()).collect();
        let ey: Vec<i32> = (0..d.eval_chunk).map(|i| (i % 10) as i32).collect();
        let s = bench("eval_chunk (xla graph, 1000 rows)", budget, || {
            black_box(xe.eval_chunk(&w, &ex, &ey).unwrap());
        });
        println!("{}", s.report());

        // End-to-end threaded round (the real per-round cost).
        println!("\n== end-to-end coordinator round (threaded, xla) ==");
        let mut cfg2 = cfg.clone();
        cfg2.train_n = 4000;
        cfg2.test_n = 1000;
        cfg2.max_rounds = 8;
        cfg2.eval_every = 1000; // no eval inside the timed window
        cfg2.target_acc = 2.0;
        let train = Arc::new(generate(cfg2.train_n, 0, &SynthConfig::default()));
        let test = Arc::new(generate(cfg2.test_n, 1, &SynthConfig::default()));
        let part = partition(&train, cfg2.m, PartitionKind::Heterogeneous, 0);
        let t0 = std::time::Instant::now();
        let mut co =
            Coordinator::new(&cfg2, train, test, &part, 0, &FailureConfig::default()).unwrap();
        let setup = t0.elapsed();
        let mut pol = parse_policy("nacfl:1").unwrap();
        let mut proc = sc.process(Rng::new(3)).unwrap();
        let t1 = std::time::Instant::now();
        co.run(pol.as_mut(), &mut proc).unwrap();
        let per_round = t1.elapsed() / cfg2.max_rounds as u32;
        println!(
            "coordinator: setup (PJRT client(s) + compile) {setup:.2?}; \
             {} rounds -> {per_round:.2?}/round",
            cfg2.max_rounds
        );
    } else {
        println!("\n(artifacts missing: PJRT benches skipped — run `make artifacts`)");
    }
}

"""Kernel-vs-oracle correctness: the CORE python-side signal.

The Pallas kernels (interpret=True) must agree with the pure-jnp oracles
in ``kernels.ref`` bit-for-bit (they implement the same ops in the same
order).  Hypothesis sweeps shapes, bit-widths and degenerate inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as kdense
from compile.kernels import quantizer as kquant
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def randn(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------


class TestInfNorm:
    @given(n=st.integers(1, 20000), seed=st.integers(0, 2**31))
    def test_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        x = randn(rng, n)
        got = kquant.inf_norm(x)[0, 0]
        assert float(got) == float(ref.inf_norm(x))

    def test_zero_vector(self):
        assert float(kquant.inf_norm(jnp.zeros(100))[0, 0]) == 0.0

    def test_single_element(self):
        assert float(kquant.inf_norm(jnp.asarray([-3.5]))[0, 0]) == 3.5

    def test_padding_does_not_leak(self):
        # Non-multiple-of-BLK length exercises the zero-padding path.
        x = -0.25 * jnp.ones(kquant.BLK + 17)
        assert float(kquant.inf_norm(x)[0, 0]) == 0.25


class TestQuantize:
    @given(
        n=st.integers(1, 30000),
        b=st.integers(1, 16),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_bitwise(self, n, b, seed):
        rng = np.random.default_rng(seed)
        x = randn(rng, n)
        u = jnp.asarray(rng.random(n).astype(np.float32))
        s = jnp.float32(2**b - 1)
        dq, norm = kquant.quantize(x, u, s)
        expect = ref.quantize_dequantize(x, u, s)
        np.testing.assert_array_equal(np.asarray(dq), np.asarray(expect))
        assert float(norm[0, 0]) == float(ref.inf_norm(x))

    def test_zero_vector_stays_zero(self):
        x = jnp.zeros(512)
        u = jnp.full(512, 0.5)
        dq, norm = kquant.quantize(x, u, jnp.float32(3.0))
        assert float(norm[0, 0]) == 0.0
        np.testing.assert_array_equal(np.asarray(dq), np.zeros(512))

    def test_max_coordinate_exact(self):
        x = jnp.asarray([2.0, -1.0, 0.5])
        u = jnp.asarray([0.9, 0.9, 0.9])
        dq, _ = kquant.quantize(x, u, jnp.float32(1.0))
        assert float(dq[0]) == 2.0

    def test_grid_property(self):
        rng = np.random.default_rng(1)
        x = randn(rng, 2048)
        u = jnp.asarray(rng.random(2048).astype(np.float32))
        s = 7.0
        dq, norm = kquant.quantize(x, u, jnp.float32(s))
        k = np.abs(np.asarray(dq)) * s / float(norm[0, 0])
        assert np.all(np.abs(k - np.round(k)) < 1e-3)
        assert np.all(np.round(k) <= s)

    def test_unbiased_on_average(self):
        rng = np.random.default_rng(2)
        x = randn(rng, 256)
        trials = 400
        acc = np.zeros(256, dtype=np.float64)
        for t in range(trials):
            u = jnp.asarray(rng.random(256).astype(np.float32))
            dq = ref.quantize_dequantize(x, u, jnp.float32(1.0))
            acc += np.asarray(dq, dtype=np.float64)
        mean = acc / trials
        norm = float(ref.inf_norm(x))
        tol = 5.0 * norm / (2.0 * np.sqrt(trials))
        np.testing.assert_allclose(mean, np.asarray(x), atol=tol)


# ---------------------------------------------------------------------------
# dense / matmul
# ---------------------------------------------------------------------------


class TestDense:
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 300),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**31),
    )
    def test_mm_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a, b = randn(rng, m, k), randn(rng, k, n)
        np.testing.assert_allclose(
            np.asarray(kdense.mm(a, b)), np.asarray(ref.mm(a, b)), atol=1e-4, rtol=1e-5
        )

    @given(m=st.integers(1, 150), seed=st.integers(0, 2**31))
    def test_dense_sigmoid_matches_ref(self, m, seed):
        rng = np.random.default_rng(seed)
        x, w, b = randn(rng, m, 40), randn(rng, 40, 17), randn(rng, 17)
        np.testing.assert_allclose(
            np.asarray(kdense.dense_sigmoid(x, w, b)),
            np.asarray(ref.dense_sigmoid(x, w, b)),
            atol=1e-6,
        )

    def test_dense_linear_matches_ref(self):
        rng = np.random.default_rng(3)
        x, w, b = randn(rng, 64, 250), randn(rng, 250, 10), randn(rng, 10)
        np.testing.assert_allclose(
            np.asarray(kdense.dense_linear(x, w, b)),
            np.asarray(ref.dense(x, w, b)),
            atol=1e-4,
        )

    def test_sigmoid_bwd_matches_ref(self):
        rng = np.random.default_rng(4)
        y = jnp.asarray(rng.random((32, 20)).astype(np.float32))
        dy = randn(rng, 32, 20)
        np.testing.assert_allclose(
            np.asarray(kdense.sigmoid_bwd(y, dy)),
            np.asarray(ref.sigmoid_bwd(y, dy)),
            atol=1e-6,
        )

    @given(seed=st.integers(0, 2**31))
    @settings(max_examples=10, deadline=None)
    def test_custom_vjp_matches_autodiff_of_ref(self, seed):
        rng = np.random.default_rng(seed)
        x, w, b = randn(rng, 12, 9), randn(rng, 9, 7), randn(rng, 7)

        def loss_kernel(w):
            return jnp.sum(kdense.dense_sigmoid(x, w, b) ** 2)

        def loss_ref(w):
            return jnp.sum(ref.dense_sigmoid(x, w, b) ** 2)

        gk = jax.grad(loss_kernel)(w)
        gr = jax.grad(loss_ref)(w)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-4, rtol=1e-4)

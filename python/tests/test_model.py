"""L2 model graphs: shapes, semantics, and lowering health."""

import jax
import jax.numpy as jnp
import numpy as np

import compile.model as M
from compile.aot import to_hlo_text


def _params(rng, scale=0.05):
    return jnp.asarray((rng.standard_normal(M.P) * scale).astype(np.float32))


class TestShapes:
    def test_flat_parameter_count(self):
        assert M.P == 784 * 250 + 250 + 250 * 10 + 10 == 198_760

    def test_flatten_unflatten_round_trip(self):
        rng = np.random.default_rng(0)
        w = _params(rng)
        w1, b1, w2, b2 = M.unflatten(w)
        assert w1.shape == (784, 250) and b1.shape == (250,)
        assert w2.shape == (250, 10) and b2.shape == (10,)
        np.testing.assert_array_equal(np.asarray(M.flatten(w1, b1, w2, b2)), np.asarray(w))

    def test_forward_logits_shape(self):
        rng = np.random.default_rng(1)
        w = _params(rng)
        x = jnp.asarray(rng.standard_normal((7, 784)).astype(np.float32))
        assert M.forward(w, x).shape == (7, 10)

    def test_lowering_specs_cover_all_graphs(self):
        specs = M.lowering_specs()
        assert set(specs) == {"local_round", "quantize", "global_step", "eval_chunk"}


class TestSemantics:
    def test_local_round_is_sum_of_grads_scaled(self):
        # update = (w - w_tau)/eta must be invariant to eta at first order;
        # for tau=1-like behavior we check the SGD identity directly:
        # w' = w - eta*update reproduces the two-step trajectory.
        rng = np.random.default_rng(2)
        w = _params(rng)
        xs = jnp.asarray(rng.standard_normal((M.TAU, 8, 784)).astype(np.float32))
        ys = jnp.asarray(rng.integers(0, 10, size=(M.TAU, 8)).astype(np.int32))
        eta = jnp.float32(0.05)
        (upd,) = M.local_round(w, xs, ys, eta)
        assert upd.shape == (M.P,)
        assert bool(jnp.all(jnp.isfinite(upd)))
        # applying the update must reduce the loss on the sampled batches
        w2 = w - eta * upd
        def loss(wv):
            tot = 0.0
            for a in range(M.TAU):
                ls, _ = M.eval_chunk(wv, xs[a], ys[a])
                tot += ls
            return tot
        assert float(loss(w2)) < float(loss(w))

    def test_eval_chunk_counts(self):
        rng = np.random.default_rng(3)
        w = _params(rng)
        x = jnp.asarray(rng.standard_normal((16, 784)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, size=(16,)).astype(np.int32))
        loss_sum, correct = M.eval_chunk(w, x, y)
        assert 0 <= int(correct) <= 16
        assert float(loss_sum) > 0.0

    def test_quantize_fn_unbiased_grid(self):
        rng = np.random.default_rng(4)
        v = jnp.asarray(rng.standard_normal(M.P).astype(np.float32))
        u = jnp.asarray(rng.random(M.P).astype(np.float32))
        dq, norm = M.quantize_fn(v, u, jnp.float32(3.0))
        k = np.abs(np.asarray(dq)) * 3.0 / float(norm[0, 0])
        assert np.all(np.abs(k - np.round(k)) < 1e-3)

    def test_global_step_axpy(self):
        rng = np.random.default_rng(5)
        w = _params(rng)
        g = _params(rng)
        (w2,) = M.global_step(w, g, jnp.float32(0.5))
        np.testing.assert_allclose(np.asarray(w2), np.asarray(w) - 0.5 * np.asarray(g), atol=1e-6)


class TestLowering:
    def test_all_graphs_lower_to_hlo_text(self):
        for name, (fn, specs) in M.lowering_specs().items():
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            assert text.startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text

"""AOT: lower every L2 graph to HLO *text* + emit golden parity vectors.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):

  local_round.hlo.txt   quantize.hlo.txt   global_step.hlo.txt
  eval_chunk.hlo.txt    manifest.json      golden/*.bin + golden/manifest.json

The golden vectors are produced by the pure-jnp oracles in ``kernels.ref``
and by the L2 graphs themselves; rust unit tests (``cargo test``) replay
them against the rust-native quantizer and MLP so the three layers share
one numeric contract.  Python never runs after this step.

Usage: python -m compile.aot [--out-dir DIR] [--skip-golden]
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_bin(path: str, arr: np.ndarray) -> dict:
    """Raw little-endian dump + shape/dtype record for the manifest."""
    a = np.ascontiguousarray(arr)
    with open(path, "wb") as f:
        if a.dtype == np.float32:
            f.write(a.astype("<f4").tobytes())
        elif a.dtype in (np.int32, np.int64):
            f.write(a.astype("<i4").tobytes())
        else:
            raise ValueError(f"unsupported golden dtype {a.dtype}")
    return {
        "file": os.path.basename(path),
        "shape": list(a.shape),
        "dtype": "f32" if a.dtype == np.float32 else "i32",
    }


def lower_all(out_dir: str) -> dict:
    entries = {}
    for name, (fn, specs) in model.lowering_specs().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "chars": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    entries["_dims"] = {
        "P": model.P,
        "D_IN": model.D_IN,
        "HIDDEN": model.HIDDEN,
        "N_CLASSES": model.N_CLASSES,
        "TAU": model.TAU,
        "BATCH": model.BATCH,
        "EVAL_CHUNK": model.EVAL_CHUNK,
    }
    return entries


def emit_golden(out_dir: str) -> None:
    """Deterministic parity vectors for the rust-side implementations."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    man = {}
    rng = np.random.default_rng(20230217)  # fixed seed: goldens are stable

    # -- quantizer parity (several bit-widths, incl. degenerate inputs) ----
    n = 4096
    x = rng.standard_normal(n).astype(np.float32)
    u = rng.random(n).astype(np.float32)
    man["quant_x"] = write_bin(os.path.join(gdir, "quant_x.bin"), x)
    man["quant_u"] = write_bin(os.path.join(gdir, "quant_u.bin"), u)
    for b in (1, 2, 3, 8):
        s = float(2**b - 1)
        dq = np.asarray(ref.quantize_dequantize(jnp.asarray(x), jnp.asarray(u), jnp.float32(s)))
        man[f"quant_dq_b{b}"] = write_bin(os.path.join(gdir, f"quant_dq_b{b}.bin"), dq)
    man["quant_norm"] = write_bin(
        os.path.join(gdir, "quant_norm.bin"),
        np.asarray([float(ref.inf_norm(jnp.asarray(x)))], dtype=np.float32),
    )

    # -- MLP parity: forward logits, eval stats, one local round ----------
    w = (rng.standard_normal(model.P) * 0.05).astype(np.float32)
    bx = rng.standard_normal((8, model.D_IN)).astype(np.float32)
    by = rng.integers(0, model.N_CLASSES, size=(8,)).astype(np.int32)
    man["mlp_w"] = write_bin(os.path.join(gdir, "mlp_w.bin"), w)
    man["mlp_x"] = write_bin(os.path.join(gdir, "mlp_x.bin"), bx)
    man["mlp_y"] = write_bin(os.path.join(gdir, "mlp_y.bin"), by)

    logits = np.asarray(model.forward(jnp.asarray(w), jnp.asarray(bx)))
    man["mlp_logits"] = write_bin(os.path.join(gdir, "mlp_logits.bin"), logits)

    loss_sum, correct = model.eval_chunk(jnp.asarray(w), jnp.asarray(bx), jnp.asarray(by))
    man["mlp_eval"] = write_bin(
        os.path.join(gdir, "mlp_eval.bin"),
        np.asarray([float(loss_sum), float(int(correct))], dtype=np.float32),
    )

    xs = rng.standard_normal((model.TAU, 8, model.D_IN)).astype(np.float32)
    ys = rng.integers(0, model.N_CLASSES, size=(model.TAU, 8)).astype(np.int32)
    eta = np.float32(0.07)
    (upd,) = model.local_round(jnp.asarray(w), jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(eta))
    man["round_xs"] = write_bin(os.path.join(gdir, "round_xs.bin"), xs)
    man["round_ys"] = write_bin(os.path.join(gdir, "round_ys.bin"), ys)
    man["round_update"] = write_bin(os.path.join(gdir, "round_update.bin"), np.asarray(upd))
    man["round_eta"] = {"value": 0.07}

    with open(os.path.join(gdir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"golden vectors -> {gdir} ({len(man)} entries)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    entries = lower_all(out_dir)
    if not args.skip_golden:
        emit_golden(out_dir)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(entries, f, indent=1)
    print(f"manifest -> {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()

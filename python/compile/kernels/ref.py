"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the mathematically transparent definition the Pallas
kernels must reproduce; ``python/tests/test_kernel.py`` asserts allclose
between kernel and oracle across shape/dtype sweeps (hypothesis), and
``aot.py`` emits golden vectors from these oracles that the rust
implementations (``rust/src/quant``, ``rust/src/model``) are tested
against — a single parity chain from paper equation to the hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def inf_norm(x: jax.Array) -> jax.Array:
    """||x||_inf of a flat vector."""
    return jnp.max(jnp.abs(x))


def quantize_dequantize(x: jax.Array, u: jax.Array, s: jax.Array) -> jax.Array:
    """Paper eq. (11): stochastic s-level quantizer, dequantized view.

    zeta_i rounds |x_i|/||x||_inf * s to floor or ceil with probability
    equal to the fractional part (unbiased).  ``u`` is uniform [0,1)
    external randomness.
    """
    s = s.astype(jnp.float32)
    norm = inf_norm(x)
    inv = jnp.where(norm > 0.0, 1.0 / norm, 0.0)
    t = jnp.abs(x) * inv * s
    low = jnp.floor(t)
    frac = t - low
    lev = jnp.minimum(low + jnp.where(u < frac, 1.0, 0.0), s)
    return jnp.sign(x) * lev * norm / s


def mm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain matmul oracle."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Affine layer: x @ w + b."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def dense_sigmoid(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused affine + logistic sigmoid."""
    return jax.nn.sigmoid(dense(x, w, b))


def sigmoid_bwd(y: jax.Array, dy: jax.Array) -> jax.Array:
    """d/dz sigmoid(z) expressed through the forward output y."""
    return dy * y * (1.0 - y)

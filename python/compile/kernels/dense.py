"""Tiled Pallas matmul / fused dense kernels with Pallas backward passes.

The (784, 250, 10) sigmoid MLP's fwd *and* bwd are expressed through one
tiled matmul kernel (:func:`mm`) plus a fused dense+sigmoid forward
(:func:`dense_sigmoid`).  ``custom_vjp`` wires the backward pass through
the same Pallas matmul (dx = dz @ W^T, dW = x^T @ dz) and an elementwise
Pallas kernel for the sigmoid gradient, so the whole training graph —
not just inference — routes through L1 kernels.

TPU mapping (DESIGN.md §Hardware-Adaptation): tiles are (M_BLK, N) with
the K dimension kept whole in VMEM — at the paper's dims the largest
operand tile is W1 (784x250 f32 = 766 KiB), far under the ~16 MiB VMEM
budget, so no K-loop accumulation is needed; ``jnp.dot`` with
``preferred_element_type=f32`` maps onto the MXU.  ``interpret=True``
everywhere (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size: one tile for the training batch (64), 8 tiles for the
# eval chunk (512); N and K stay whole (small at the paper's dims).
M_BLK = 64


def _ceil_to(n: int, b: int) -> int:
    return ((n + b - 1) // b) * b


# --------------------------------------------------------------------------
# Tiled matmul
# --------------------------------------------------------------------------


def _mm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def mm(a: jax.Array, b: jax.Array, *, m_blk: int = M_BLK) -> jax.Array:
    """a @ b via a Pallas kernel tiled over rows of ``a``.

    Pads M up to a tile multiple (zero rows contribute zero outputs and
    are sliced away); K and N are kept whole per tile.
    """
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    m, k = a.shape
    n = b.shape[1]
    mb = min(m_blk, _ceil_to(m, 8))
    mp = _ceil_to(m, mb)
    ap = jnp.pad(a, ((0, mp - m), (0, 0))) if mp != m else a
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // mb,),
        in_specs=[
            pl.BlockSpec((mb, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((mb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(ap, b)
    return out[:m]


# --------------------------------------------------------------------------
# Fused dense (+ sigmoid) forward
# --------------------------------------------------------------------------


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, sigmoid: bool):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...]
    o_ref[...] = jax.nn.sigmoid(z) if sigmoid else z


def _dense_fwd_pallas(x, w, b, sigmoid: bool, m_blk: int = M_BLK):
    m, k = x.shape
    n = w.shape[1]
    mb = min(m_blk, _ceil_to(m, 8))
    mp = _ceil_to(m, mb)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    b2 = jnp.reshape(b, (1, n))
    out = pl.pallas_call(
        lambda x_ref, w_ref, b_ref, o_ref: _dense_kernel(
            x_ref, w_ref, b_ref, o_ref, sigmoid=sigmoid
        ),
        grid=(mp // mb,),
        in_specs=[
            pl.BlockSpec((mb, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((mb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(xp, w, b2)
    return out[:m]


# --------------------------------------------------------------------------
# Elementwise sigmoid-gradient kernel
# --------------------------------------------------------------------------


def _sig_bwd_kernel(y_ref, dy_ref, o_ref):
    y = y_ref[...]
    o_ref[...] = dy_ref[...] * y * (1.0 - y)


def sigmoid_bwd(y: jax.Array, dy: jax.Array) -> jax.Array:
    """dz = dy * y * (1 - y) as an elementwise Pallas kernel."""
    assert y.shape == dy.shape and y.ndim == 2
    m, n = y.shape
    return pl.pallas_call(
        _sig_bwd_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(y, dy)


# --------------------------------------------------------------------------
# custom_vjp wrappers: the MLP's building blocks
# --------------------------------------------------------------------------


@jax.custom_vjp
def dense_sigmoid(x, w, b):
    """y = sigmoid(x @ w + b), Pallas fwd and Pallas bwd."""
    return _dense_fwd_pallas(x, w, b, sigmoid=True)


def _ds_fwd(x, w, b):
    y = _dense_fwd_pallas(x, w, b, sigmoid=True)
    return y, (x, w, y)


def _ds_bwd(res, dy):
    x, w, y = res
    dz = sigmoid_bwd(y, dy)
    dx = mm(dz, jnp.transpose(w))
    dw = mm(jnp.transpose(x), dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense_sigmoid.defvjp(_ds_fwd, _ds_bwd)


@jax.custom_vjp
def dense_linear(x, w, b):
    """y = x @ w + b (logits layer), Pallas fwd and Pallas bwd."""
    return _dense_fwd_pallas(x, w, b, sigmoid=False)


def _dl_fwd(x, w, b):
    y = _dense_fwd_pallas(x, w, b, sigmoid=False)
    return y, (x, w)


def _dl_bwd(res, dy):
    x, w = res
    dx = mm(dy, jnp.transpose(w))
    dw = mm(jnp.transpose(x), dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense_linear.defvjp(_dl_fwd, _dl_bwd)

"""Stochastic infinity-norm quantizer as Pallas kernels (paper eq. (11)).

The compressor used by every policy in the paper is the QSGD-style
stochastic quantizer

    Q_q(x, b) = ||x||_inf * sign(x) * zeta(x, b)

where ``zeta`` uniformly quantizes ``|x_i| / ||x||_inf`` onto ``s = 2^b - 1``
levels with unbiased stochastic rounding.  On the wire a client sends the
sign bits, the per-coordinate level integers (b bits each) and the norm
(32 bits), i.e. ``s(b) = d*(b+1) + 32`` bits; the server *dequantizes* to
``norm * sign * level / s``.  These kernels compute the server-side
dequantized view directly (what the aggregation consumes), plus the norm.

Two kernels:

  * :func:`inf_norm` — single-pass blocked max-|x| reduction.
  * :func:`quantize_dequantize` — elementwise stochastic round given the
    norm, the level count ``s`` (a runtime scalar, so one compiled artifact
    serves every bit-width b in {1..32}) and externally supplied uniform
    randomness ``u`` (supplied by the rust coordinator's PRNG so the
    rust-side and python-side quantizers are bit-for-bit comparable).

TPU mapping (DESIGN.md §Hardware-Adaptation): both kernels tile the flat
parameter vector with ``BlockSpec((BLK,))`` so each tile (input + uniforms
+ output, 3*BLK*4 bytes = 96 KiB at BLK=8192) sits in VMEM; the norm is a
two-pass HBM->VMEM schedule (reduce, then broadcast as a scalar operand)
instead of a GPU warp reduction.  Lowered with ``interpret=True`` for the
CPU PJRT runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size for the flat vector kernels.
#
# Perf iteration (EXPERIMENTS.md §Perf L1-1): at the paper's P = 198,760
# a single 2^18 tile (1 MiB/operand, ~3 MiB total — comfortably inside a
# TPU core's ~16 MiB VMEM) turns the interpret-mode grid loop into one
# step and is 4.9x faster than the original BLK = 8192 (25 grid steps);
# larger models fall back to the grid automatically.
BLK = 262_144


def _pad_to_multiple(x: jax.Array, blk: int) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % blk
    if rem == 0:
        return x
    return jnp.pad(x, (0, rem))


# --------------------------------------------------------------------------
# inf-norm reduction kernel
# --------------------------------------------------------------------------


def _inf_norm_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0, 0] = 0.0

    o_ref[0, 0] = jnp.maximum(o_ref[0, 0], jnp.max(jnp.abs(x_ref[...])))


def inf_norm(x: jax.Array, *, blk: int = BLK) -> jax.Array:
    """max(|x|) over a 1-D vector, as a blocked Pallas reduction.

    Returns a (1, 1) f32 array (scalar layout shared with the quantize
    kernel's norm operand).
    """
    assert x.ndim == 1, "inf_norm expects a flat vector"
    xp = _pad_to_multiple(x, blk)  # zero padding never changes max|x| >= 0
    grid = (xp.shape[0] // blk,)
    return pl.pallas_call(
        _inf_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(xp)


# --------------------------------------------------------------------------
# quantize-dequantize kernel
# --------------------------------------------------------------------------


def _quantize_kernel(x_ref, u_ref, norm_ref, s_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    norm = norm_ref[0, 0]
    s = s_ref[0, 0]
    # Guard the all-zero vector: inv = 0 makes t = 0 everywhere and the
    # output collapses to sign(x)*0 = 0, which is the exact answer.
    inv = jnp.where(norm > 0.0, 1.0 / norm, 0.0)
    t = jnp.abs(x) * inv * s  # in [0, s]
    low = jnp.floor(t)
    frac = t - low
    lev = low + jnp.where(u < frac, 1.0, 0.0)  # unbiased stochastic round
    # t == s exactly (|x_i| == norm) gives low = s, frac = 0 -> lev = s. A
    # float blip t = s + eps would give lev = s + 1; clamp for safety.
    lev = jnp.minimum(lev, s)
    o_ref[...] = jnp.sign(x) * lev * norm / s


def quantize_dequantize(
    x: jax.Array,
    u: jax.Array,
    norm: jax.Array,
    s: jax.Array,
    *,
    blk: int = BLK,
) -> jax.Array:
    """Stochastically quantize ``x`` to ``s`` levels and dequantize.

    Args:
      x:    flat f32 vector (the pre-compression client update).
      u:    uniforms in [0, 1), same shape as ``x`` (external randomness).
      norm: (1, 1) f32 — ``||x||_inf`` (from :func:`inf_norm`).
      s:    (1, 1) f32 — level count ``2^b - 1`` as a *runtime* scalar.

    Returns the dequantized vector ``norm * sign(x) * lev / s`` with
    ``E[out] = x`` (unbiased, Assumption 8).
    """
    assert x.ndim == 1 and x.shape == u.shape
    n = x.shape[0]
    xp = _pad_to_multiple(x, blk)
    up = _pad_to_multiple(u, blk)
    grid = (xp.shape[0] // blk,)
    out = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        interpret=True,
    )(xp, up, norm, s)
    return out[:n]


@functools.partial(jax.jit, static_argnames=())
def quantize(x: jax.Array, u: jax.Array, s: jax.Array):
    """Full compressor: norm reduction + stochastic quantize-dequantize.

    ``s`` may be shaped () or (1, 1); returns ``(dequantized, norm)`` with
    norm shaped (1, 1).  This is the graph lowered to
    ``artifacts/quantize.hlo.txt`` and run by the rust coordinator for
    every (client, round) pair.
    """
    s2 = jnp.reshape(s.astype(jnp.float32), (1, 1))
    norm = inf_norm(x)
    dq = quantize_dequantize(x, u, norm, s2)
    return dq, norm

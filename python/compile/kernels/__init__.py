"""L1 Pallas kernels (build-time only; lowered with interpret=True).

Modules:
  quantizer -- stochastic infinity-norm quantizer (paper eq. (11)):
               inf-norm reduction kernel + quantize-dequantize kernel.
  dense     -- tiled matmul / fused dense(+sigmoid) kernels used by the
               (784, 250, 10) MLP, with a custom_vjp whose backward pass
               is also expressed with the pallas matmul kernel.
  ref       -- pure-jnp oracles for every kernel (the correctness contract
               checked by python/tests).
"""

"""L2: the paper's FL compute graphs in JAX, built on the L1 Pallas kernels.

The paper trains a fully-connected (784, 250, 10) network with a sigmoid
hidden layer on (heterogeneously partitioned) MNIST via FedCOM-V
(Algorithm 2): each round every client runs ``tau = 2`` local SGD steps
from the broadcast global model and sends the *pre-compressed update*
``g_j = (w^n - w_j^{tau+1,n}) / eta_n`` (the sum of its local stochastic
gradients); the server averages stochastically-quantized updates and steps
``w^{n+1} = w^n - eta_n * gamma_n * mean_j Q(g_j)``.

Everything here is build-time only.  ``aot.py`` lowers four graphs to HLO
text; the rust coordinator (L3) loads them once and drives every round
through PJRT:

  local_round   (w[P], xs[TAU,B,784], ys[TAU,B] i32, eta)   -> update[P]
  quantize_fn   (v[P], u[P], s)                             -> (dq[P], norm)
  global_step   (w[P], agg[P], eta_gamma)                   -> w'[P]
  eval_chunk    (w[P], x[E,784], y[E] i32)                  -> (loss_sum, correct)

Parameters travel as ONE flat f32 vector (layout below) so the rust side
marshals a single literal and the quantizer consumes the update without
re-layout — exactly what goes on the wire in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import dense as kdense
from .kernels import quantizer as kquant

# Paper dimensions (section IV-A5).
D_IN = 784
HIDDEN = 250
N_CLASSES = 10
TAU = 2        # local computations per round
BATCH = 64     # client minibatch per local step
EVAL_CHUNK = 1000  # test/train evaluation chunk size

# Flat parameter layout: [W1 | b1 | W2 | b2]
_SIZES = (D_IN * HIDDEN, HIDDEN, HIDDEN * N_CLASSES, N_CLASSES)
P = sum(_SIZES)  # 198,760


def unflatten(w: jax.Array):
    """Split the flat parameter vector into (W1, b1, W2, b2)."""
    o1 = _SIZES[0]
    o2 = o1 + _SIZES[1]
    o3 = o2 + _SIZES[2]
    w1 = jnp.reshape(w[:o1], (D_IN, HIDDEN))
    b1 = w[o1:o2]
    w2 = jnp.reshape(w[o2:o3], (HIDDEN, N_CLASSES))
    b2 = w[o3:]
    return w1, b1, w2, b2


def flatten(w1, b1, w2, b2) -> jax.Array:
    return jnp.concatenate(
        [jnp.ravel(w1), jnp.ravel(b1), jnp.ravel(w2), jnp.ravel(b2)]
    )


def forward(w: jax.Array, x: jax.Array) -> jax.Array:
    """Logits for a batch ``x`` [B, 784] under flat params ``w``."""
    w1, b1, w2, b2 = unflatten(w)
    h = kdense.dense_sigmoid(x, w1, b1)
    return kdense.dense_linear(h, w2, b2)


def _ce_loss_mean(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Mean cross-entropy over the batch (y: int32 labels)."""
    logits = forward(w, x)
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    return jnp.mean(logz - picked)


def local_round(w: jax.Array, xs: jax.Array, ys: jax.Array, eta: jax.Array):
    """FedCOM-V local stage: TAU SGD steps, return the pre-compressed update.

    xs: [TAU, B, 784], ys: [TAU, B] — a fresh minibatch per local step
    (Algorithm 2 line 5).  Returns ``(w - w_final) / eta`` which equals the
    sum of the TAU stochastic gradients.
    """
    eta = jnp.reshape(eta, ())
    wk = w
    for a in range(TAU):  # static unroll; TAU is a paper constant
        g = jax.grad(_ce_loss_mean)(wk, xs[a], ys[a])
        wk = wk - eta * g
    return ((w - wk) / eta,)


def quantize_fn(v: jax.Array, u: jax.Array, s: jax.Array):
    """Stochastic quantize-dequantize of an update vector (L1 kernel)."""
    dq, norm = kquant.quantize(v, u, s)
    return dq, norm


def global_step(w: jax.Array, agg: jax.Array, eta_gamma: jax.Array):
    """Server step: w' = w - eta*gamma * mean-aggregated dequantized update."""
    return (w - jnp.reshape(eta_gamma, ()) * agg,)


def eval_chunk(w: jax.Array, x: jax.Array, y: jax.Array):
    """Summed CE loss and correct-prediction count over an eval chunk."""
    logits = forward(w, x)
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    loss_sum = jnp.sum(logz - picked)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.int32))
    return loss_sum, correct


# ---------------------------------------------------------------------------
# Example-input specs for lowering (shapes/dtypes only).
# ---------------------------------------------------------------------------


def lowering_specs():
    f32, i32 = jnp.float32, jnp.int32
    sd = jax.ShapeDtypeStruct
    return {
        "local_round": (
            local_round,
            (sd((P,), f32), sd((TAU, BATCH, D_IN), f32), sd((TAU, BATCH), i32), sd((), f32)),
        ),
        "quantize": (
            quantize_fn,
            (sd((P,), f32), sd((P,), f32), sd((), f32)),
        ),
        "global_step": (
            global_step,
            (sd((P,), f32), sd((P,), f32), sd((), f32)),
        ),
        "eval_chunk": (
            eval_chunk,
            (sd((P,), f32), sd((EVAL_CHUNK, D_IN), f32), sd((EVAL_CHUNK,), i32)),
        ),
    }

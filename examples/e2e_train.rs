//! End-to-end driver (DESIGN.md deliverable (b)/E2E): the full system on
//! a real small workload.
//!
//! All three layers compose here: the rust coordinator (threaded
//! leader/worker round pipeline, NAC-FL policy engine, AR(1) log-normal
//! congestion) drives the AOT-compiled JAX/Pallas graphs through PJRT to
//! train the paper's (784, 250, 10) MLP on the 60k-sample heterogeneous
//! corpus until 90 % test accuracy, for NAC-FL and the Fixed-Error
//! baseline on the same sample path.  Loss curves land in
//! `results/e2e_*.csv` and the run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_train`
//! (falls back to the pure-rust engine when artifacts are missing).

use nacfl::config::ExperimentConfig;
use nacfl::coordinator::{Coordinator, FailureConfig};
use nacfl::data::{partition, synth};
use nacfl::netsim::Scenario;
use nacfl::policy::parse_policy;
use nacfl::runtime::Runtime;
use nacfl::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::paper();
    cfg.scenario = nacfl::netsim::ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: 4.0 };
    cfg.max_rounds = 600;
    cfg.engine = if Runtime::artifacts_present(&cfg.artifact_dir) {
        "xla".into()
    } else {
        eprintln!("artifacts missing; using the pure-rust engine (run `make artifacts`)");
        "rust".into()
    };

    // Full-size corpus: 60k train / 10k test, one label per client.
    eprintln!("generating 60k/10k synthetic corpus...");
    let sc = synth::SynthConfig::default();
    let train = Arc::new(synth::generate_with_protos(
        cfg.train_n,
        cfg.data_seed,
        cfg.data_seed,
        &sc,
    ));
    let test = Arc::new(synth::generate_with_protos(
        cfg.test_n,
        cfg.data_seed,
        cfg.data_seed ^ 0x7e57_da7a,
        &sc,
    ));
    let part = partition(&train, cfg.m, cfg.partition, cfg.data_seed);
    std::fs::create_dir_all("results")?;

    let mut summary = Vec::new();
    for spec in ["nacfl:1", "error:5.25"] {
        let started = std::time::Instant::now();
        let mut policy = parse_policy(spec)?;
        // Same seed => same congestion path: sample-path-paired runs.
        let mut process = Scenario::new(cfg.scenario, cfg.m)
            .process(Rng::new(0).derive("net", 0))?;
        let mut coordinator = Coordinator::new(
            &cfg,
            Arc::clone(&train),
            Arc::clone(&test),
            &part,
            /*seed=*/ 0,
            &FailureConfig::default(),
        )?;
        eprintln!("[{spec}] training on engine `{}`...", cfg.engine);
        let trace = coordinator.run(policy.as_mut(), &mut process)?;
        let csv = format!("results/e2e_{}.csv", spec.replace([':', '.'], "_"));
        trace.write_csv(&csv)?;
        let t90 = trace.time_to_accuracy(cfg.target_acc);
        let last = trace.points.last().unwrap();
        println!(
            "[{spec}] rounds {:>4}  final acc {:>5.1}%  time-to-90% {}  ({:.1?} real, csv -> {csv})",
            last.round,
            last.test_acc * 100.0,
            t90.map(|t| format!("{t:.4e} sim-s"))
                .unwrap_or_else(|| "not reached".into()),
            started.elapsed(),
        );
        summary.push((spec, t90));
    }

    if let (Some(nac), Some(err)) = (summary[0].1, summary[1].1) {
        println!(
            "\nNAC-FL vs Fixed-Error on this path: {:.4e} vs {:.4e} sim-s ({:+.1}% gain)",
            nac,
            err,
            (err / nac - 1.0) * 100.0
        );
    }
    Ok(())
}

//! Quickstart: the NAC-FL public API in ~60 lines.
//!
//! Builds a small synthetic federated dataset, instantiates the paper's
//! congestion model and policy roster, and trains the (784, 250, 10)
//! MLP with FedCOM-V under NAC-FL, printing the simulated wall clock as
//! it goes.  Uses the pure-rust engine so it runs before `make
//! artifacts`; pass `--engine xla` (via the `nacfl` CLI) for the
//! AOT/PJRT path.
//!
//! Run: `cargo run --release --example quickstart`

use nacfl::config::ExperimentConfig;
use nacfl::data::synth::{generate, SynthConfig};
use nacfl::data::{partition, PartitionKind};
use nacfl::fl::engine::RustEngine;
use nacfl::fl::fedcom::{run_fedcom, FedcomOptions};
use nacfl::netsim::{Scenario, ScenarioKind};
use nacfl::policy::parse_policy;
use nacfl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Experiment config: the paper's hyperparameters, scaled down.
    let mut cfg = ExperimentConfig::paper();
    cfg.train_n = 5_000;
    cfg.test_n = 1_000;
    cfg.eval_samples = 1_000;
    cfg.train_eval_samples = 1_000;
    cfg.max_rounds = 150;
    cfg.eval_every = 5;
    cfg.engine = "rust".into();
    cfg.scenario = ScenarioKind::PartiallyCorrelated { sigma_inf_sq: 4.0 };

    // 2. Data: synthetic MNIST-like corpus, one label per client (the
    //    paper's heterogeneous FL setting).
    let sc = SynthConfig::default();
    let train = generate(cfg.train_n, cfg.data_seed, &sc);
    let test = generate(cfg.test_n, cfg.data_seed ^ 1, &sc);
    let part = partition(&train, cfg.m, PartitionKind::Heterogeneous, 0);

    // 3. Congestion: partially correlated BTD (paper §IV-A2).
    let scenario = Scenario::new(cfg.scenario, cfg.m);
    let mut process = scenario.process(Rng::new(0).derive("net", 0))?;

    // 4. Policy + engine, then train.
    let mut policy = parse_policy("nacfl:1")?;
    let mut engine = RustEngine::new();
    println!("training with {} under {}...", policy.name(), cfg.scenario.label());
    let trace = run_fedcom(
        &cfg,
        &train,
        &test,
        &part,
        policy.as_mut(),
        &mut process,
        &mut engine,
        /*seed=*/ 0,
        &FedcomOptions::default(),
    )?;

    for p in &trace.points {
        println!(
            "round {:>4}  simulated wall {:>11.3e} s  train loss {:>7.4}  test acc {:>5.1}%  mean bits {:>5.2}",
            p.round,
            p.wall,
            p.train_loss,
            p.test_acc * 100.0,
            p.mean_bits
        );
    }
    match trace.time_to_accuracy(cfg.target_acc) {
        Some(t) => println!("\nreached 90% test accuracy at {t:.3e} simulated seconds"),
        None => println!("\nrun the full-size example (e2e_train) to reach 90%"),
    }
    Ok(())
}

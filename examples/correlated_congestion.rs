//! Correlated-congestion study (the paper's Table III headline).
//!
//! Sweeps the asymptotic variance sigma_inf^2 of the perfectly-correlated
//! BTD process and reports, per policy, the mean time to target plus
//! NAC-FL's sample-path gain — showing the paper's core finding: the
//! NAC-FL advantage over Fixed-Error grows with temporal correlation,
//! because Fixed-Error's per-round variance budget cannot shift work
//! between calm and congested stretches.
//!
//! Run: `cargo run --release --example correlated_congestion`

use nacfl::config::ExperimentConfig;
use nacfl::exp::{cell_results, execute, ExecOptions, ExperimentPlan, RunRecord, Tier};
use nacfl::metrics::{gain_vs, Summary};
use nacfl::netsim::ScenarioKind;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::paper();
    cfg.seeds = (0..20).collect();
    let tier = Tier::Analytic { k_eps: 300.0 };

    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>16} {:>12}",
        "sigma_inf^2", "fixed:1 mean", "fixed:2 mean", "error mean", "nacfl mean", "gain vs FE"
    );
    for si2 in [1.0, 1.5625, 4.0, 16.0, 64.0] {
        cfg.scenario = ScenarioKind::PerfectlyCorrelated { sigma_inf_sq: si2 };
        let plan = ExperimentPlan::run_cell_plan("correlated", &cfg, tier);
        let summary = execute(&plan, &ExecOptions::default(), &mut [])?;
        let refs: Vec<&RunRecord> = summary.records.iter().collect();
        let results = cell_results(&refs);
        let by = |prefix: &str| {
            results
                .iter()
                .find(|r| r.policy.starts_with(prefix))
                .unwrap()
        };
        let nac = by("nacfl");
        let fe = by("error");
        println!(
            "{:<12} {:>14.4e} {:>14.4e} {:>14.4e} {:>16.4e} {:>11.1}%",
            si2,
            Summary::of(&by("fixed:1").times).mean,
            Summary::of(&by("fixed:2").times).mean,
            Summary::of(&fe.times).mean,
            Summary::of(&nac.times).mean,
            gain_vs(&nac.times, &fe.times),
        );
    }
    println!(
        "\npaper reference (Table III gains vs Fixed-Error): 13% @ 1.56, 27% @ 4, 21% @ 16 —\n\
         the monotone-in-correlation trend is the reproduction target (DESIGN.md §6)."
    );
    Ok(())
}

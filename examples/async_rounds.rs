//! DES discipline tour: what breaking round synchrony buys you.
//!
//! Runs the paper's policy roster under the heterogeneous-independent
//! congestion scenario with two injected stragglers (clients 8 and 9
//! upload 8x slower), across the three aggregation disciplines:
//!
//! * `sync`        — wait for everyone (the paper's setting);
//! * `semi-sync:7` — aggregate after the fastest 7 of 10;
//! * `async:0.5`   — aggregate on every arrival, staleness-discounted.
//!
//! The sweep fans out over the work-stealing grid executor, and the
//! merged table shows mean time-to-target per (discipline, policy).
//!
//! Run: `cargo run --release --example async_rounds`

use nacfl::config::ExperimentConfig;
use nacfl::des::{Discipline, FaultModel};
use nacfl::exp::{run_sweep, sweep_table, SweepSpec};
use nacfl::netsim::ScenarioKind;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let spec = SweepSpec {
        m: cfg.m,
        scenarios: vec![ScenarioKind::HeterogeneousIndependent],
        disciplines: vec![
            Discipline::Sync,
            Discipline::SemiSync { k: 7 },
            Discipline::Async { staleness_exp: 0.5 },
        ],
        policies: cfg.policies.clone(),
        seeds: (0..10).collect(),
        faults: FaultModel::none().with_stragglers(cfg.m, &[8, 9], 8.0),
        k_eps: 100.0,
        max_rounds: 1_000_000,
    };

    println!(
        "sweeping {} disciplines x {} policies x {} seeds on all cores...\n",
        spec.disciplines.len(),
        spec.policies.len(),
        spec.seeds.len()
    );
    let cells = run_sweep(&ctx, &spec, 0)?;
    let table = sweep_table("heterog + stragglers: mean time-to-target", &spec, &cells)?;
    println!("{}", table.render());

    for d in &spec.disciplines {
        let sel: Vec<_> = cells.iter().filter(|c| c.discipline == d.label()).collect();
        let n = sel.len().max(1) as f64;
        let round = sel.iter().map(|c| c.result.mean_round_duration()).sum::<f64>() / n;
        let late = sel.iter().map(|c| c.result.late_updates).sum::<usize>() as f64 / n;
        let rho = sel.iter().map(|c| c.result.mean_rho).sum::<f64>() / n;
        println!(
            "{:<14} mean round {round:>10.3e} s   late updates/run {late:>7.1}   mean rho_eff {rho:.3}",
            d.label()
        );
    }
    println!(
        "\nsemi-sync stops waiting for the stragglers (shorter rounds, higher rho_eff);\n\
         async removes the barrier entirely — the trade NAC-FL navigates per round."
    );
    Ok(())
}

//! DES discipline tour: what breaking round synchrony buys you.
//!
//! Runs the paper's policy roster under the heterogeneous-independent
//! congestion scenario with two injected stragglers (clients 8 and 9
//! upload 8x slower), across the three aggregation disciplines:
//!
//! * `sync`        — wait for everyone (the paper's setting);
//! * `semi-sync:7` — aggregate after the fastest 7 of 10;
//! * `async:0.5`   — aggregate on every arrival, staleness-discounted.
//!
//! The disciplines are one axis of an `ExperimentPlan`; the campaign
//! engine fans the runs over the work-stealing pool and the merged
//! table shows mean time-to-target per (discipline, policy).
//!
//! Run: `cargo run --release --example async_rounds`

use nacfl::config::ExperimentConfig;
use nacfl::des::Discipline;
use nacfl::exp::{campaign_table, execute, ExecOptions, ExperimentPlan, Tier};
use nacfl::netsim::ScenarioKind;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::paper();
    cfg.scenario = ScenarioKind::HeterogeneousIndependent;
    cfg.seeds = (0..10).collect();
    cfg.stragglers = vec![8, 9];
    cfg.straggler_mult = 8.0;
    let plan = ExperimentPlan::builder("async rounds")
        .base(cfg)
        .tiers(vec![Tier::Analytic { k_eps: 100.0 }])
        .disciplines(vec![
            Discipline::Sync,
            Discipline::SemiSync { k: 7 },
            Discipline::Async { staleness_exp: 0.5 },
        ])
        .build()?;

    println!(
        "sweeping {} disciplines x {} policies x {} seeds on all cores...\n",
        plan.disciplines.len(),
        plan.policies.len(),
        plan.seeds.len()
    );
    let summary = execute(&plan, &ExecOptions::default(), &mut [])?;
    let table =
        campaign_table("heterog + stragglers: mean time-to-target", &plan, &summary.records)?;
    println!("{}", table.render());

    for d in &plan.disciplines {
        let label = d.label();
        let sel: Vec<_> =
            summary.records.iter().filter(|r| r.discipline == label).collect();
        let n = sel.len().max(1) as f64;
        let per_round: Vec<f64> = sel
            .iter()
            .filter(|r| r.rounds > 0)
            .map(|r| r.wall / r.rounds as f64)
            .collect();
        let round = per_round.iter().sum::<f64>() / per_round.len().max(1) as f64;
        let late = sel.iter().map(|r| r.late).sum::<usize>() as f64 / n;
        let agg = sel.iter().map(|r| r.aggregations).sum::<usize>() as f64 / n;
        println!(
            "{label:<14} mean round {round:>10.3e} s   late updates/run {late:>7.1}   \
             aggregations/run {agg:>8.0}"
        );
    }
    println!(
        "\nsemi-sync stops waiting for the stragglers (shorter rounds, more late \
         updates);\nasync removes the barrier entirely — the trade NAC-FL navigates \
         per round."
    );
    Ok(())
}

//! Tour of the policy engine internals.
//!
//! Walks through: (1) how NAC-FL's eq.-(6) argmin shifts per-client
//! bit-widths as congestion moves; (2) the running estimates (r_hat,
//! d_hat) converging (Theorem 1) toward the eq.-(4) oracle optimum on a
//! finite Markov chain; (3) operating on in-band probe *estimates* of
//! the BTD (paper §V) instead of the true state.
//!
//! Run: `cargo run --release --example policy_tour`

use nacfl::config::ExperimentConfig;
use nacfl::netsim::estimator::ProbeEstimator;
use nacfl::netsim::{MarkovChain, NetworkProcess};
use nacfl::policy::{CompressionPolicy, NacFl, OraclePolicy};
use nacfl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::paper();
    let ctx = cfg.policy_ctx();
    let m = cfg.m;

    // -- (1) congestion-dependent compression ---------------------------
    println!("== (1) NAC-FL bit choices vs congestion (10 clients) ==");
    let mut nac = NacFl::new(1.0);
    for _ in 0..200 {
        nac.choose(&ctx, &vec![1.0; m]); // burn in the estimates
    }
    for (label, state) in [
        ("calm     (c = 0.3)", vec![0.3; 10]),
        ("baseline (c = 1.0)", vec![1.0; 10]),
        ("congested(c = 5.0)", vec![5.0; 10]),
        ("mixed fast/slow", vec![0.2, 0.2, 0.2, 0.2, 0.2, 4.0, 4.0, 4.0, 4.0, 4.0]),
    ] {
        let mut p = nac.clone();
        let levels: Vec<u8> = p.choose(&ctx, &state).iter().map(|x| x.level).collect();
        println!("  {label:<22} -> bits {levels:?}");
    }

    // -- (2) Theorem-1 convergence to the oracle ------------------------
    println!("\n== (2) NAC-FL estimates vs the eq.(4) oracle (finite Markov chain) ==");
    let mut srng = Rng::new(12);
    let states: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..m).map(|_| srng.normal_ms(1.0, 1.0).exp()).collect())
        .collect();
    let mut chain = MarkovChain::uniform_mixing(states, 0.4, Rng::new(5))?;
    let oracle = OraclePolicy::solve(&ctx, &chain);
    println!(
        "  oracle: E[rho] = {:.4}  E[d] = {:.4e}  objective = {:.4e}",
        oracle.expected_rho,
        oracle.expected_d,
        oracle.objective()
    );
    let mut nac = NacFl::new(1.0);
    for n in 1..=20_000usize {
        let c = chain.next_state();
        nac.choose(&ctx, &c);
        if [10, 100, 1000, 20_000].contains(&n) {
            let (r, d) = nac.estimates();
            println!(
                "  after {n:>6} rounds: r_hat*d_hat = {:.4e}  (gap {:+.2}%)",
                r * d,
                (r * d / oracle.objective() - 1.0) * 100.0
            );
        }
    }

    // -- (3) probing instead of perfect observation ---------------------
    println!("\n== (3) policy on in-band probe estimates (paper section V) ==");
    let mut probe = ProbeEstimator::new(m, 0.5, 0.25, Rng::new(3));
    let mut nac_est = NacFl::new(1.0);
    let mut nac_true = NacFl::new(1.0);
    let mut chain2 = MarkovChain::uniform_mixing(
        (0..4)
            .map(|i| vec![0.5 * (i + 1) as f64; m])
            .collect(),
        0.5,
        Rng::new(8),
    )?;
    let mut agree = 0usize;
    let rounds = 500;
    for _ in 0..rounds {
        let c_true = chain2.next_state();
        let c_est = probe.observe(&c_true);
        let bt = nac_true.choose(&ctx, &c_true);
        let be = nac_est.choose(&ctx, &c_est);
        if bt == be {
            agree += 1;
        }
    }
    println!(
        "  with 25% probe noise, estimated-state choices matched true-state \
         choices in {agree}/{rounds} rounds"
    );
    Ok(())
}
